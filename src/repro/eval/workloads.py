"""Workload generators for the paper's long-running-read experiments.

Three families, all runnable on any registered backend through one driver
(``repro.eval.driver``):

  * ``longread``  — the headline regime (paper Figs. 1/6/7): dedicated
    updater threads commit word transfers while scanner threads run ONE
    transaction each that reads an entire region via ``Txn.read_bulk``
    (chunked, so updaters genuinely interleave mid-scan).  Variants scale
    the scan size; every completed scan checks the balance invariant, so
    throughput and snapshot consistency are measured together.  This is
    the workload where unversioned TMs starve and Multiverse/MVStore pull
    ahead — the paper's central claim, now measured through a batched
    read path so the numbers reflect the algorithm, not the interpreter.
  * ``rwmix``     — the WRITE-HEAVY headline (paper SS5's update
    throughput): dedicated updater threads commit whole-block rewrites
    (write sets large enough to engage the batched commit pipeline —
    bulk lock-acquire, scatter write-back, bulk release) over disjoint
    block sets, while a checker thread bulk-reads random blocks and
    verifies the block-sum invariant (a torn commit snapshot counts as
    a violation and fails the CLI).  This is the low-contention
    update-heavy regime where unversioned TMs are supposed to win; the
    headline asks whether Multiverse's update throughput stays within
    2x of the best unversioned baseline.
  * ``serving``   — the SERVING headline (the paper's production
    scenario): the ``repro.serve`` subsystem answers open-loop request
    traffic from MVStore parameter snapshots while a trainer thread
    commits every few milliseconds.  "Backends" here are serving
    policies over the same store — ``multiverse`` (Mode-U ring,
    per-request pinned clocks), ``modeq`` (Mode-Q validation: a commit
    since pin aborts the request, which restarts at a fresh clock) and
    ``unversioned`` (always read live, never abort — requests silently
    mix parameter versions).  Rows carry qps + p50/p95/p99 latency +
    shed/abort counts from the serving telemetry; the headline asks
    whether Mode U sustains target QPS with flat p99 and zero torn
    reads while Mode Q's abort/restart path inflates tail latency or
    sheds outright.
  * ``structrq``  — data-structure long reads over ``repro.structs``
    (hashmap / extbst / abtree): reader threads run whole-structure
    range queries (size queries on the hashmap) while a dedicated
    updater commits size-preserving key moves, the Fig. 6/7 shape.
    Every completed query checks the size invariant (``violations``),
    and each trial ends with a quiescent reference measurement — the
    same backend scanning an EQUAL number of flat words through
    ``read_bulk`` — so the headline ratio (``rq_vs_scan``) states how
    close the frontier-at-a-time struct traversal comes to an array
    scan of the same volume (it was interpreter-bound before the
    traversal layer).

Workload objects expose ``variants(quick)`` -> [TrialSpec] and
``run_trial(backend, spec, seed)`` -> row dict; the driver owns threads,
warmup and the results file.  Every RNG derives from the trial seed, so
a results row names the exact op stream it measured.
"""
from __future__ import annotations

import dataclasses
import random
from typing import Dict, List

import numpy as np

from repro.api import MaxRetriesExceeded, make_tm, run
from repro.configs.paper_stm import MultiverseParams
from repro.structs import STRUCTS

#: every backend the eval drives by default (the paper's comparison set)
DEFAULT_BACKENDS = ("multiverse", "tl2", "dctl", "norec", "tinystm",
                    "mvstore")
#: unversioned baselines (the "every baseline starves" side of the claim)
UNVERSIONED = ("tl2", "dctl", "norec", "tinystm")

INITIAL = 100          # per-word prefill: transfers preserve region sums
AMOUNT = 5


@dataclasses.dataclass(frozen=True)
class TrialSpec:
    """One (workload variant x backend) trial, fully named."""

    workload: str
    variant: str                 # display label ("scan4096", "hashmap")
    n_readers: int
    n_updaters: int
    duration_s: float
    warmup_s: float
    params: Dict                 # workload-specific knobs

    @property
    def total_threads(self) -> int:
        return self.n_readers + self.n_updaters


def _tm_params() -> MultiverseParams:
    # K thresholds count ATTEMPTS; eval scans cost ~ms per attempt (vs
    # ~0.1ms on the paper's EPYC), so thresholds scale down to keep the
    # same wall-clock engagement point (same reasoning as benchmarks/).
    # K3=3: a Mode-Q versioned scanner can abort on every fresh-written
    # unversioned address, so the Q->QtoU CAS must engage within a few
    # attempts or short trials measure the livelock, not the steady state
    return MultiverseParams(k1=2, k2=3, k3=3, lock_table_bits=12)


def _make(backend: str, n_threads: int, params=None):
    params = params or _tm_params()
    if backend in ("mvstore", "shardstore"):
        return make_tm(backend, n_threads, params=params)
    # numeric word workloads run on the int64 array heap so read_bulk
    # gathers are single fancy-indexes / kernel launches
    return make_tm(backend, n_threads, params=params, array_heap=True)


def _batch_sum(vals) -> int:
    if isinstance(vals, np.ndarray):
        return int(vals.sum())
    return sum(int(v) for v in vals)


# ---------------------------------------------------------------------------
# longread: frequent updaters + whole-region scanners
# ---------------------------------------------------------------------------


class LongReadWorkload:
    name = "longread"
    metric = "scans_per_sec"

    def variants(self, quick: bool = False) -> List[TrialSpec]:
        if quick:
            # window must outlive the Q->QtoU->U transition transient or
            # the smoke measures the mode machinery engaging, not the TM
            sizes, dur, warm = (512,), 0.8, 0.3
        else:
            sizes, dur, warm = (256, 1024, 4096), 1.5, 0.3
        return [TrialSpec(
            workload=self.name, variant=f"scan{n}", n_readers=1,
            n_updaters=2, duration_s=dur, warmup_s=warm,
            params=dict(scan_size=n, chunk=256, scanner_retries=60,
                        updater_retries=2000),
        ) for n in sizes]

    def run_trial(self, backend: str, spec: TrialSpec, seed: int) -> Dict:
        from repro.eval.driver import time_trial
        p = spec.params
        scan, chunk = p["scan_size"], p["chunk"]
        tm = _make(backend, spec.total_threads)
        base = tm.alloc(scan, INITIAL)
        expected = scan * INITIAL

        def scanner(tid, stop, c):
            def scan_tx(tx):
                tot = 0
                for off in range(0, scan, chunk):
                    hi = min(off + chunk, scan)
                    tot += _batch_sum(tx.read_bulk(
                        range(base + off, base + hi)))
                return tot
            while not stop.is_set():
                try:
                    tot = run(tm, scan_tx, tid=tid,
                              max_retries=p["scanner_retries"])
                    c["scans"] += 1
                    if tot != expected:
                        c["violations"] += 1
                except MaxRetriesExceeded:
                    c["failed_scans"] += 1

        def updater(tid, stop, c):
            r = random.Random(seed * 10007 + 100 + tid)
            def transfer(tx):
                i = r.randrange(scan)
                j = r.randrange(scan - 1)
                if j >= i:
                    j += 1
                a = tx.read(base + i)
                b = tx.read(base + j)
                tx.write(base + i, a - AMOUNT)
                tx.write(base + j, b + AMOUNT)
            while not stop.is_set():
                try:
                    run(tm, transfer, tid=tid,
                        max_retries=p["updater_retries"])
                    c["updates"] += 1
                except MaxRetriesExceeded:
                    c["failed_updates"] += 1

        workers = [lambda stop, c, t=t: scanner(t, stop, c)
                   for t in range(spec.n_readers)]
        workers += [lambda stop, c, t=t: updater(spec.n_readers + t,
                                                 stop, c)
                    for t in range(spec.n_updaters)]
        counters, dt = time_trial(workers, spec)
        stats = tm.stats()
        tm.stop()
        return {
            "workload": self.name, "backend": backend,
            "tm": backend, "variant": spec.variant, "seed": seed,
            "scan_size": scan, "chunk": chunk,
            "scans_per_sec": counters["scans"] / dt,
            "failed_scans": counters["failed_scans"],
            "violations": counters["violations"],
            "updates_per_sec": counters["updates"] / dt,
            "failed_updates": counters["failed_updates"],
            "mode_transitions": stats.get("mode_transitions", 0),
            "stm_stats": stats,
        }


# ---------------------------------------------------------------------------
# rwmix: every thread mixes point transfers with bulk reads
# ---------------------------------------------------------------------------


class RWMixWorkload:
    """Write-heavy blocks + a consistency checker (see module docstring).

    The region is ``n_blocks`` aligned blocks of ``write_words`` words,
    prefilled so every block sums to ``write_words * INITIAL``.  Each
    updater owns the blocks congruent to its id (disjoint write sets —
    the measured quantity is the commit pipeline, not inter-updater
    conflict resolution) and commits a sum-preserving ROTATION of one
    block per transaction: one ``read_bulk`` of the block, one
    ``write_bulk`` of its values shifted by one.  The checker
    bulk-reads random blocks; a completed read whose sum is off is a
    torn commit snapshot (``violations`` — the CLI exits non-zero on
    any).

    Two sizing notes the numbers depend on.  The lock table is LARGE
    (2^16): block-disjoint address sets still alias in a hashed lock
    table, and at 2^12 two concurrent 1k-word claims share hundreds of
    lock words — the trial would measure aliasing thrash, not the
    commit pipeline (a real deployment sizes its lock table for its
    write sets the same way).  And updater throughput leans on the
    bulk write path's SNAPSHOT EXTENSION (``engine/commit.py``): under
    the deferred clock every back-to-back update would otherwise eat
    one doomed attempt per commit, which at 1k-word transactions is
    half the runtime.
    """

    name = "rwmix"
    metric = "updates_per_sec"

    def variants(self, quick: bool = False) -> List[TrialSpec]:
        sizes = (512,) if quick else (256, 1024)
        dur, warm = (0.8, 0.3) if quick else (1.2, 0.3)
        return [TrialSpec(
            workload=self.name, variant=f"w{wb}", n_readers=1,
            n_updaters=2, duration_s=dur, warmup_s=warm,
            params=dict(write_words=wb, n_blocks=8, max_retries=2000),
        ) for wb in sizes]

    def run_trial(self, backend: str, spec: TrialSpec, seed: int) -> Dict:
        from repro.eval.driver import time_trial
        p = spec.params
        wb, n_blocks = p["write_words"], p["n_blocks"]
        n_upd = spec.n_updaters
        # update-heavy steady state = the paper's Mode-Q regime: keep the
        # go-versioned / mode-CAS thresholds high so a checker that races
        # a block rewrite just retries unversioned (its re-read is cheap)
        # instead of versioning whole blocks and dragging every updater
        # onto the version-append path
        tm = _make(backend, spec.total_threads,
                   params=MultiverseParams(k1=30, k2=200, k3=200,
                                           lock_table_bits=16))
        base = tm.alloc(wb * n_blocks, INITIAL)
        block_sum = wb * INITIAL

        def updater(tid, stop, c):
            r = random.Random(seed * 10007 + 300 + tid)
            mine = [b for b in range(n_blocks) if b % n_upd == tid]

            def rotate(tx):
                off = base + wb * mine[r.randrange(len(mine))]
                vals = np.asarray(tx.read_bulk(range(off, off + wb)),
                                  np.int64)
                tx.write_bulk(range(off, off + wb), np.roll(vals, 1))
            while not stop.is_set():
                try:
                    run(tm, rotate, tid=tid,
                        max_retries=p["max_retries"])
                    c["updates"] += 1
                except MaxRetriesExceeded:
                    c["failed_updates"] += 1

        def checker(tid, stop, c):
            r = random.Random(seed * 10007 + 900 + tid)

            def check(tx):
                off = base + wb * r.randrange(n_blocks)
                return _batch_sum(tx.read_bulk(range(off, off + wb)))
            while not stop.is_set():
                try:
                    got = run(tm, check, tid=tid,
                              max_retries=p["max_retries"])
                    c["checks"] += 1
                    if got != block_sum:
                        c["violations"] += 1
                except MaxRetriesExceeded:
                    c["failed_checks"] += 1

        workers = [lambda stop, c, t=t: updater(t, stop, c)
                   for t in range(n_upd)]
        workers += [lambda stop, c, t=t: checker(n_upd + t, stop, c)
                    for t in range(spec.n_readers)]
        counters, dt = time_trial(workers, spec)
        stats = tm.stats()
        tm.stop()
        return {
            "workload": self.name, "backend": backend, "tm": backend,
            "variant": spec.variant, "seed": seed,
            "write_words": wb, "n_blocks": n_blocks,
            "updates_per_sec": counters["updates"] / dt,
            "failed_updates": counters["failed_updates"],
            "checks_per_sec": counters["checks"] / dt,
            "failed_checks": counters["failed_checks"],
            "violations": counters["violations"],
            "mode_transitions": stats.get("mode_transitions", 0),
            "stm_stats": stats,
        }


# ---------------------------------------------------------------------------
# shardscale: disjoint-block updaters across 1/2/4 store shards
# ---------------------------------------------------------------------------


def _shard_parity_check(seed: int, wb: int, n_blocks: int, params) -> bool:
    """Drive one deterministic single-thread history through BOTH
    ``shardstore(n_shards=1, span=wb)`` and ``mvstore`` and compare the
    final heaps bit-for-bit.

    At one shard the address routing is the identity and the shard-local
    clock IS the store clock, so the sharded store must be
    indistinguishable from the unsharded one — this is the conformance
    anchor the scaling claim hangs off (the 2- and 4-shard rows are only
    meaningful if shard==1 is exactly the baseline)."""
    r = random.Random(seed * 7919 + 17)
    ops = [(r.randrange(n_blocks), 1 + r.randrange(wb - 1))
           for _ in range(24)]
    heaps = []
    for backend, kw in (("mvstore", {}),
                        ("shardstore", dict(n_shards=1, span=wb))):
        tm = make_tm(backend, 1, params=params, **kw)
        base = tm.alloc(wb * n_blocks, INITIAL)

        def ramp(tx):
            # constant prefill would make rotations invisible; stamp a
            # per-word ramp so any routing slip changes the final heap
            tx.write_bulk(range(base, base + wb * n_blocks),
                          np.arange(wb * n_blocks, dtype=np.int64) * 3 + 7)
        run(tm, ramp, tid=0)
        for b, k in ops:
            off = base + wb * b

            def rot(tx, off=off, k=k):
                vals = np.asarray(tx.read_bulk(range(off, off + wb)),
                                  np.int64)
                tx.write_bulk(range(off, off + wb), np.roll(vals, k))
            run(tm, rot, tid=0)

        def dump(tx):
            return np.asarray(
                tx.read_bulk(range(base, base + wb * n_blocks)), np.int64)
        heaps.append(run(tm, dump, tid=0))
        tm.stop()
    return bool(np.array_equal(heaps[0], heaps[1]))


class ShardScaleWorkload:
    """Disjoint-block scaling across store shards (see ISSUE: the
    two-level clock's payoff).

    Same geometry as rwmix — ``n_blocks`` span-aligned blocks of
    ``write_words`` words, two updaters owning the blocks congruent to
    their id, a sum checker — but the store is a ``shardstore`` with
    ``span=write_words``, so block ``b`` lives wholly on shard
    ``b % n_shards`` and the two updaters' footprints land on DISJOINT
    shards for every ``n_shards >= 2``.  At one shard both updaters
    share a single commit clock: every interleaved publish stales the
    other's pin and forces a full re-read/re-write attempt.  At two
    shards each updater ticks its own shard-local clock and commits
    conflict-free — the measured speedup is exactly the abort/retry
    waste the per-shard clocks eliminate (total heap words are IDENTICAL
    at every shard count; nothing else changes).

    The shard==1 row additionally carries ``parity_ok``: a deterministic
    dual-drive of the same history through shardstore(1) and mvstore
    comparing final heaps bit-for-bit (the conformance anchor)."""

    name = "shardscale"
    metric = "updates_per_sec"
    default_backends = ("shardstore",)
    #: CLI override (``--shards``); None = the variant defaults below
    shards = None

    def variants(self, quick: bool = False) -> List[TrialSpec]:
        counts = self.shards or ((1, 2) if quick else (1, 2, 4))
        wb = 512
        dur, warm = (0.8, 0.3) if quick else (1.2, 0.3)
        return [TrialSpec(
            workload=self.name, variant=f"s{n}", n_readers=1,
            n_updaters=2, duration_s=dur, warmup_s=warm,
            params=dict(n_shards=n, write_words=wb, n_blocks=8,
                        max_retries=2000),
        ) for n in counts]

    def run_trial(self, backend: str, spec: TrialSpec, seed: int) -> Dict:
        from repro.eval.driver import time_trial
        p = spec.params
        wb, n_blocks = p["write_words"], p["n_blocks"]
        n_shards = p["n_shards"]
        n_upd = spec.n_updaters
        params = MultiverseParams(k1=30, k2=200, k3=200,
                                  lock_table_bits=16)
        if backend == "shardstore":
            tm = make_tm(backend, spec.total_threads, params=params,
                         n_shards=n_shards, span=wb)
        else:
            # unsharded comparison rows (n_shards is recorded but moot)
            tm = _make(backend, spec.total_threads, params=params)
        base = tm.alloc(wb * n_blocks, INITIAL)
        block_sum = wb * INITIAL

        def updater(tid, stop, c):
            r = random.Random(seed * 10007 + 300 + tid)
            mine = [b for b in range(n_blocks) if b % n_upd == tid]

            def rotate(tx):
                off = base + wb * mine[r.randrange(len(mine))]
                vals = np.asarray(tx.read_bulk(range(off, off + wb)),
                                  np.int64)
                tx.write_bulk(range(off, off + wb), np.roll(vals, 1))
            while not stop.is_set():
                try:
                    run(tm, rotate, tid=tid,
                        max_retries=p["max_retries"])
                    c["updates"] += 1
                except MaxRetriesExceeded:
                    c["failed_updates"] += 1

        def checker(tid, stop, c):
            r = random.Random(seed * 10007 + 900 + tid)

            def check(tx):
                off = base + wb * r.randrange(n_blocks)
                return _batch_sum(tx.read_bulk(range(off, off + wb)))
            while not stop.is_set():
                try:
                    got = run(tm, check, tid=tid,
                              max_retries=p["max_retries"])
                    c["checks"] += 1
                    if got != block_sum:
                        c["violations"] += 1
                except MaxRetriesExceeded:
                    c["failed_checks"] += 1

        workers = [lambda stop, c, t=t: updater(t, stop, c)
                   for t in range(n_upd)]
        workers += [lambda stop, c, t=t: checker(n_upd + t, stop, c)
                    for t in range(spec.n_readers)]
        counters, dt = time_trial(workers, spec)
        stats = tm.stats()
        tm.stop()
        parity = None
        if backend == "shardstore" and n_shards == 1:
            parity = _shard_parity_check(seed, wb, n_blocks, params)
        return {
            "workload": self.name, "backend": backend, "tm": backend,
            "variant": spec.variant, "seed": seed,
            "n_shards": n_shards, "write_words": wb,
            "n_blocks": n_blocks,
            "updates_per_sec": counters["updates"] / dt,
            "failed_updates": counters["failed_updates"],
            "checks_per_sec": counters["checks"] / dt,
            "failed_checks": counters["failed_checks"],
            "violations": counters["violations"],
            "cross_shard_commits": stats.get("cross_shard_commits", 0),
            "parity_ok": parity,
            "mode_transitions": stats.get("mode_transitions", 0),
            "stm_stats": stats,
        }


# ---------------------------------------------------------------------------
# structrq: data-structure ops with range queries as the long reads
# ---------------------------------------------------------------------------


class StructRQWorkload:
    name = "structrq"
    metric = "rqs_per_sec"
    #: store-level substrate works too but every struct op is a whole
    #: mv_commit — prefill-bound; opt in via --backends
    default_backends = ("multiverse", "tl2", "dctl", "norec", "tinystm")

    def variants(self, quick: bool = False) -> List[TrialSpec]:
        structs = ("hashmap",) if quick else ("hashmap", "extbst",
                                              "abtree")
        dur, warm = (0.5, 0.15) if quick else (1.5, 0.3)
        prefill = 200 if quick else 800
        return [TrialSpec(
            workload=self.name, variant=s, n_readers=2, n_updaters=1,
            duration_s=dur, warmup_s=warm,
            params=dict(structure=s, prefill=prefill,
                        key_range=prefill * 4, chunk=256,
                        max_retries=150, ref_window_s=0.25),
        ) for s in structs]

    def run_trial(self, backend: str, spec: TrialSpec, seed: int) -> Dict:
        import time

        from repro.eval.driver import time_trial
        p = spec.params
        kind = p["structure"]
        prefill = p["prefill"]
        # structs store only ints here, so word backends run on the
        # int64 array heap — same substrate the flat-scan reference uses
        tm = _make(backend, spec.total_threads)
        cls = STRUCTS[kind]
        s = cls(tm, n_buckets=1 << 10) if kind == "hashmap" else cls(tm)
        rnd = random.Random(42 + seed)
        filled = 0
        while filled < prefill:
            k = rnd.randrange(p["key_range"])
            if run(tm, lambda tx, k=k: s.insert(tx, k, k), tid=0):
                filled += 1

        # the long read: whole-structure range/size query.  The size is
        # invariant under the updater's key moves, so a completed query
        # that does not see exactly `prefill` keys is a torn snapshot.
        if kind == "hashmap":
            def rq(tx):
                return s.size_query(tx)
        else:
            def rq(tx):
                return len(s.range_query(tx, 0, prefill + 1))

        def reader(tid, stop, c):
            while not stop.is_set():
                try:
                    got = run(tm, rq, tid=tid,
                              max_retries=p["max_retries"])
                    c["rqs"] += 1
                    if got != prefill:
                        c["violations"] += 1
                except MaxRetriesExceeded:
                    c["failed_ops"] += 1

        def updater(tid, stop, c):
            r = random.Random(seed * 10007 + 700 + tid)

            def move(tx):
                ka = r.randrange(p["key_range"])
                kb = r.randrange(p["key_range"])
                if s.delete(tx, ka):
                    if not s.insert(tx, kb, kb):
                        s.insert(tx, ka, ka)   # kb existed: put ka back
            while not stop.is_set():
                try:
                    run(tm, move, tid=tid, max_retries=p["max_retries"])
                    c["updates"] += 1
                except MaxRetriesExceeded:
                    c["failed_updates"] += 1

        workers = [lambda stop, c, t=t: reader(t, stop, c)
                   for t in range(spec.n_readers)]
        workers += [lambda stop, c, t=t: updater(spec.n_readers + t,
                                                 stop, c)
                    for t in range(spec.n_updaters)]
        counters, dt = time_trial(workers, spec)

        # quiescent reference: the SAME backend + heap, single thread —
        # the struct query vs a flat read_bulk scan over exactly as many
        # words, chunked like the longread scanner.  The ratio is the
        # headline: how close a pointer-chasing long read comes to an
        # equivalent-size array scan now that it traverses in batches.
        words = {}

        def probe(tx):
            got = rq(tx)
            words["n"] = tx.read_count
            return got

        violations = counters["violations"]
        if run(tm, probe, tid=0) != prefill:
            violations += 1
        rq_words = int(words["n"])
        chunk = p["chunk"]
        flat = tm.alloc(rq_words, 1)

        def scan(tx):
            tot = 0
            for off in range(0, rq_words, chunk):
                hi = min(off + chunk, rq_words)
                tot += _batch_sum(tx.read_bulk(
                    range(flat + off, flat + hi)))
            return tot

        def solo_rate(fn):
            run(tm, fn, tid=0)                 # warm (mode/clock settle)
            n, t0 = 0, time.perf_counter()
            while time.perf_counter() - t0 < p["ref_window_s"]:
                run(tm, fn, tid=0)
                n += 1
            return n / (time.perf_counter() - t0)

        rq_solo = solo_rate(rq)
        scan_solo = solo_rate(scan)
        stats = tm.stats()
        tm.stop()
        return {
            "workload": self.name, "backend": backend, "tm": backend,
            "variant": spec.variant, "seed": seed, "structure": kind,
            "rqs_per_sec": counters["rqs"] / dt,
            "failed_ops": counters["failed_ops"],
            "violations": violations,
            "updates_per_sec": counters["updates"] / dt,
            "failed_updates": counters["failed_updates"],
            "rq_words": rq_words,
            "rq_solo_per_sec": rq_solo,
            "arrayscan_per_sec": scan_solo,
            "rq_vs_scan": rq_solo / max(scan_solo, 1e-12),
            "mode_transitions": stats.get("mode_transitions", 0),
            "stm_stats": stats,
        }


# ---------------------------------------------------------------------------
# serving: open-loop request traffic from snapshots under live commits
# ---------------------------------------------------------------------------


class ServingWorkload:
    """Continuous-batching service vs serving-policy baselines.

    Each trial runs ``repro.serve.SnapshotService.synthetic`` — a
    committing trainer thread + the slot scheduler answering open-loop
    traffic — under one serving policy.  The trial's knobs pin the
    starvation geometry: the commit interval sits just above the
    request span, so Mode-Q requests usually meet a commit mid-flight
    and pay the abort/restart tax while Mode-U requests ride the ring.
    Unlike the word-level workloads there are no worker threads to
    time here (the service owns its loop), so ``run_trial`` does not
    go through ``time_trial``.
    """

    name = "serving"
    metric = "p99_ms"
    default_backends = ("multiverse", "modeq", "unversioned")
    POLICY = {"multiverse": "U", "modeq": "Q", "unversioned": "live"}

    def variants(self, quick: bool = False) -> List[TrialSpec]:
        # commit interval ABOVE the ~20ms request span = the one-abort
        # latency-tax regime; BELOW it = the starvation regime where
        # Mode-Q requests abort until admission fails them (see
        # serve/service.py).  The headline reads the HIGHEST-qps point,
        # so quick and full both end on the starvation geometry — the
        # unambiguous side of the claim.
        if quick:
            points = ((50.0, 1.2, 0.012),)
        else:
            points = ((60.0, 2.5, 0.028), (120.0, 2.5, 0.012))
        return [TrialSpec(
            workload=self.name, variant=f"qps{int(qps)}", n_readers=4,
            n_updaters=1, duration_s=dur, warmup_s=0.0,
            params=dict(target_qps=qps, n_slots=4, max_new=12,
                        work_s=0.0015, commit_interval_s=ci,
                        queue_depth=64, wait_budget_s=0.5,
                        max_request_aborts=8),
        ) for qps, dur, ci in points]

    def run_trial(self, backend: str, spec: TrialSpec, seed: int) -> Dict:
        from repro.serve import ServiceConfig, SnapshotService
        try:
            policy = self.POLICY[backend]
        except KeyError:
            raise ValueError(
                f"serving backend must be one of "
                f"{sorted(self.POLICY)}, got {backend!r}") from None
        p = spec.params
        cfg = ServiceConfig(
            mode=policy, n_slots=p["n_slots"], max_new=p["max_new"],
            queue_depth=p["queue_depth"],
            wait_budget_s=p["wait_budget_s"],
            max_request_aborts=p["max_request_aborts"],
            target_qps=p["target_qps"], duration_s=spec.duration_s,
            commit_interval_s=p["commit_interval_s"],
            work_s=p["work_s"], seed=seed)
        svc = SnapshotService.synthetic(cfg)
        row = svc.run_open_loop()
        row["stm_stats"]["backend"] = backend
        row.update({
            "workload": self.name, "backend": backend, "tm": backend,
            "variant": spec.variant, "seed": seed,
            "mode_transitions": 0,
        })
        return row


# ---------------------------------------------------------------------------
# reliability: rwmix under a seeded kill schedule + crash recovery
# ---------------------------------------------------------------------------


class ReliabilityWorkload:
    """rwmix's sum-preserving rotations while a seeded ``FaultSchedule``
    kills an updater roughly every ``kill_every`` commits mid-publish.

    Each kill leaves the crash image intact (held locks, a possibly
    half-published commit); the dying worker's slot runs recovery
    (``recover_engine`` — roll the decided commit forward or the
    undecided one back, sweep orphaned locks, repair torn mirror rows),
    consults ``runtime/elastic.rescale_plan`` for the degraded and
    re-admitted fleet shapes, and rejoins under the same tid — the
    supervisor restart loop collapsed into the worker thread.

    Correctness is the rwmix checker (any completed read whose block sum
    is off is a torn snapshot) PLUS a post-trial invariant sweep: lock
    table empty, no torn mirror rows, clock monotone, every block sum
    conserved.  Both land in ``violations`` so the CLI's exit gate sees
    them.  The ``nofault`` variant is the same trial without a schedule:
    the headline asks what fraction of fault-free throughput survives
    the kill/recover cycle.
    """

    name = "reliability"
    metric = "updates_per_sec"
    default_backends = ("multiverse", "tl2", "dctl")
    #: CLI ``--durable``: journal every commit to an fsync'd WAL during
    #: the trial, and hand the log to recovery so rolled-forward commits
    #: get their COMPLETE marker — the kill/recover cycle measured WITH
    #: the durability tax it would pay in production
    durable = False

    def variants(self, quick: bool = False) -> List[TrialSpec]:
        dur, warm = (0.6, 0.2) if quick else (1.2, 0.3)
        kill_every = 60 if quick else 200   # quick trials are short:
        #                                     keep several kills in frame
        return [TrialSpec(
            workload=self.name, variant=v, n_readers=1, n_updaters=2,
            duration_s=dur, warmup_s=warm,
            params=dict(write_words=256, n_blocks=8, max_retries=2000,
                        kill_every=k),
        ) for v, k in (("nofault", 0), (f"kill{kill_every}", kill_every))]

    def run_trial(self, backend: str, spec: TrialSpec, seed: int) -> Dict:
        from repro.eval.driver import time_trial
        from repro.reliability import faultpoints as FP
        from repro.reliability.recovery import (check_engine_invariants,
                                                recover_engine)
        from repro.runtime.elastic import rescale_plan
        p = spec.params
        wb, n_blocks = p["write_words"], p["n_blocks"]
        n_upd = spec.n_updaters
        # same sizing rationale as rwmix: large lock table, thresholds
        # that keep the checker unversioned (see RWMixWorkload notes)
        tm = _make(backend, spec.total_threads,
                   params=MultiverseParams(k1=30, k2=200, k3=200,
                                           lock_table_bits=16))
        base = tm.alloc(wb * n_blocks, INITIAL)
        block_sum = wb * INITIAL
        eng = getattr(tm, "raw", tm)
        clock0 = eng.clock.load()
        wal_dir = None
        if self.durable:
            import tempfile
            from repro.reliability.wal import WriteAheadLog, attach_wal
            wal_dir = tempfile.mkdtemp(prefix="repro-wal-")
            attach_wal(tm, WriteAheadLog(wal_dir, group_sync=True))
        sched = None
        if p["kill_every"]:
            # one commit = one pre_claim + one pre_release arrival, so
            # 2*kill_every arrivals ~= a kill every kill_every commits;
            # the point mix exercises BOTH recovery directions (pre_claim
            # kills roll back, pre_release kills roll forward)
            sched = FP.FaultSchedule(
                seed=seed, kill_every=2 * p["kill_every"],
                points=("pre_claim", "pre_release"), action="kill")
            FP.install(sched)

        def updater(tid, stop, c):
            r = random.Random(seed * 10007 + 300 + tid)
            mine = [b for b in range(n_blocks) if b % n_upd == tid]

            def rotate(tx):
                off = base + wb * mine[r.randrange(len(mine))]
                vals = np.asarray(tx.read_bulk(range(off, off + wb)),
                                  np.int64)
                tx.write_bulk(range(off, off + wb), np.roll(vals, 1))
            while not stop.is_set():
                try:
                    run(tm, rotate, tid=tid,
                        max_retries=p["max_retries"])
                    c["updates"] += 1
                except MaxRetriesExceeded:
                    c["failed_updates"] += 1
                except FP.SimulatedCrash:
                    # worker dies mid-publish: recover its slot, plan the
                    # degraded + re-admitted fleet, rejoin at the same tid
                    c["kills"] += 1
                    rep = recover_engine(tm, [tid], wal=eng.wal
                                         if wal_dir else None)
                    c["rolled_forward"] += len(rep.rolled_forward)
                    c["rolled_back"] += len(rep.rolled_back)
                    rescale_plan(n_devices=max(1, n_upd - 1),
                                 model_parallel=1, global_batch=n_blocks,
                                 old_microbatches=1)
                    rescale_plan(n_devices=n_upd, model_parallel=1,
                                 global_batch=n_blocks, old_microbatches=1)
                    c["recoveries"] += 1

        def checker(tid, stop, c):
            r = random.Random(seed * 10007 + 900 + tid)

            def check(tx):
                off = base + wb * r.randrange(n_blocks)
                return _batch_sum(tx.read_bulk(range(off, off + wb)))
            while not stop.is_set():
                try:
                    got = run(tm, check, tid=tid,
                              max_retries=p["max_retries"])
                    c["checks"] += 1
                    if got != block_sum:
                        c["violations"] += 1
                except MaxRetriesExceeded:
                    c["failed_checks"] += 1

        workers = [lambda stop, c, t=t: updater(t, stop, c)
                   for t in range(n_upd)]
        workers += [lambda stop, c, t=t: checker(n_upd + t, stop, c)
                    for t in range(spec.n_readers)]
        try:
            counters, dt = time_trial(workers, spec)
        finally:
            if sched is not None:
                FP.uninstall()
                FP.reset_thread()
        post = check_engine_invariants(
            tm, clock_at_least=clock0,
            expect_sums=[(base + wb * b, wb, block_sum)
                         for b in range(n_blocks)])
        stats = tm.stats()
        wal_stats = {}
        if wal_dir is not None:
            import shutil
            wal_stats = eng.wal.stats()
            eng.wal.close()
            eng.wal = None
            shutil.rmtree(wal_dir, ignore_errors=True)
        tm.stop()
        return {
            "workload": self.name, "backend": backend, "tm": backend,
            "variant": spec.variant, "seed": seed,
            "write_words": wb, "n_blocks": n_blocks,
            "durable": bool(self.durable), "wal_stats": wal_stats,
            "kill_every": p["kill_every"],
            "updates_per_sec": counters["updates"] / dt,
            "failed_updates": counters["failed_updates"],
            "checks_per_sec": counters["checks"] / dt,
            "failed_checks": counters["failed_checks"],
            "kills": counters["kills"],
            "recoveries": counters["recoveries"],
            "rolled_forward": counters["rolled_forward"],
            "rolled_back": counters["rolled_back"],
            "violations": counters["violations"] + len(post),
            "post_invariant_failures": post,
            "mode_transitions": stats.get("mode_transitions", 0),
            "stm_stats": stats,
        }


# ---------------------------------------------------------------------------
# durability: rwmix commit throughput with vs without the fsync'd WAL,
# plus a whole-process restart drill on the durable log
# ---------------------------------------------------------------------------


class DurabilityWorkload:
    """rwmix's sum-preserving rotations, in-memory vs durable.

    Two variants on identical op streams: ``inmem`` is the plain rwmix
    commit pipeline; ``durable`` attaches a ``reliability.wal``
    WriteAheadLog, so every commit buffers a PREPARE before its claim
    and fsyncs a DECIDE at the publish flip.  The headline asks what
    fraction of in-memory commit throughput survives the durability tax
    (>= 0.5x — the fsync batches with group commit, it doesn't gate
    every scatter).

    The durable trial ends with a RESTART DRILL: the engine that ran
    the trial is discarded wholesale, a FRESH engine replays the log
    via ``recover_from_wal``, and every block sum must still be
    conserved on the rebuilt heap.  Drill failures land in
    ``violations`` so the CLI's non-zero-exit gate sees them alongside
    the live checker's torn-snapshot count.
    """

    name = "durability"
    metric = "updates_per_sec"
    # tl2 = the buffered WAL hook (PREPARE before claim, DECIDE at the
    # publish flip), dctl = the encounter hook (prepare+decide collapse
    # at the decide point) — together they cover both journaling
    # flavors, and both policies have a fused group-commit path so the
    # *-group variants measure the amortized configuration the headline
    # gates on.  multiverse's durable operation is exercised by
    # ``reliability --durable`` (its versioned write sets commit solo).
    default_backends = ("tl2", "dctl")

    def variants(self, quick: bool = False) -> List[TrialSpec]:
        dur, warm = (0.6, 0.2) if quick else (1.2, 0.3)
        return [TrialSpec(
            workload=self.name, variant=v, n_readers=1, n_updaters=2,
            duration_s=dur, warmup_s=warm,
            params=dict(write_words=256, n_blocks=8, max_retries=2000,
                        durable=d, grouped=g),
        ) for v, d, g in (("inmem", False, False),
                          ("durable", True, False),
                          ("inmem-group", False, True),
                          ("durable-group", True, True))]

    def run_trial(self, backend: str, spec: TrialSpec, seed: int) -> Dict:
        import shutil
        import tempfile
        from repro.eval.driver import time_trial
        from repro.reliability.recovery import check_engine_invariants
        from repro.reliability.wal import (WriteAheadLog, attach_wal,
                                           recover_from_wal)
        from repro.core.engine.errors import AbortTx
        from repro.core.engine.groupcommit import CommitBatcher
        p = spec.params
        wb, n_blocks = p["write_words"], p["n_blocks"]
        n_upd = spec.n_updaters
        grouped = bool(p.get("grouped"))
        mk_params = MultiverseParams(k1=30, k2=200, k3=200,
                                     lock_table_bits=16)
        # group variants hand every batch member its own descriptor:
        # member tids are the block ids, checkers sit above them
        n_threads = (n_blocks + spec.n_readers if grouped
                     else spec.total_threads)
        tm = _make(backend, n_threads, params=mk_params)
        base = tm.alloc(wb * n_blocks, INITIAL)
        block_sum = wb * INITIAL
        eng = getattr(tm, "raw", tm)
        clock0 = eng.clock.load()
        wal_dir = None
        if p["durable"]:
            wal_dir = tempfile.mkdtemp(prefix="repro-wal-")
            attach_wal(tm, WriteAheadLog(wal_dir, group_sync=True))

        def updater(tid, stop, c):
            r = random.Random(seed * 10007 + 300 + tid)
            mine = [b for b in range(n_blocks) if b % n_upd == tid]

            def rotate(tx):
                off = base + wb * mine[r.randrange(len(mine))]
                vals = np.asarray(tx.read_bulk(range(off, off + wb)),
                                  np.int64)
                tx.write_bulk(range(off, off + wb), np.roll(vals, 1))
            while not stop.is_set():
                try:
                    run(tm, rotate, tid=tid,
                        max_retries=p["max_retries"])
                    c["updates"] += 1
                except MaxRetriesExceeded:
                    c["failed_updates"] += 1

        def group_updater(worker, stop, c):
            # one txn per owned block, disjoint write sets -> one fused
            # publish and (durable) ONE journal fsync per batch
            mine = [b for b in range(n_blocks) if b % n_upd == worker]
            batcher = CommitBatcher(eng)
            while not stop.is_set():
                txs = []
                for b in mine:
                    off = base + wb * b
                    for _attempt in range(4):
                        tx = eng.begin(b)
                        try:
                            vals = np.asarray(
                                tx.read_bulk(range(off, off + wb)),
                                np.int64)
                            tx.write_bulk(range(off, off + wb),
                                          np.roll(vals, 1))
                            txs.append(tx)
                            break
                        except AbortTx:
                            continue
                for tx in txs:
                    batcher.add(tx)
                ok = batcher.commit_all()
                good = sum(ok)
                c["updates"] += good
                c["failed_updates"] += len(ok) - good
            c["groups"] = batcher.stats["groups"]
            c["grouped_members"] = batcher.stats["grouped"]

        def checker(tid, stop, c):
            r = random.Random(seed * 10007 + 900 + tid)

            def check(tx):
                off = base + wb * r.randrange(n_blocks)
                return _batch_sum(tx.read_bulk(range(off, off + wb)))
            while not stop.is_set():
                try:
                    got = run(tm, check, tid=tid,
                              max_retries=p["max_retries"])
                    c["checks"] += 1
                    if got != block_sum:
                        c["violations"] += 1
                except MaxRetriesExceeded:
                    c["failed_checks"] += 1

        upd_fn = group_updater if grouped else updater
        chk_base = n_blocks if grouped else n_upd
        workers = [lambda stop, c, t=t: upd_fn(t, stop, c)
                   for t in range(n_upd)]
        workers += [lambda stop, c, t=t: checker(chk_base + t, stop, c)
                    for t in range(spec.n_readers)]
        counters, dt = time_trial(workers, spec)
        post = check_engine_invariants(
            tm, clock_at_least=clock0,
            expect_sums=[(base + wb * b, wb, block_sum)
                         for b in range(n_blocks)])
        stats = tm.stats()
        wal_stats: Dict = {}
        replayed = 0
        drill_failures: List = []
        if wal_dir is not None:
            wal_stats = eng.wal.stats()
            eng.wal.close()
            eng.wal = None
            tm.stop()
            # restart drill: the process image is gone — only the log
            # survives, and the fresh engine must conserve every block
            fresh = _make(backend, 1, params=mk_params)
            fresh.alloc(wb * n_blocks, INITIAL)
            rep = recover_from_wal(wal_dir, fresh)
            replayed = rep.wal_records_replayed
            drill_failures = check_engine_invariants(
                fresh, expect_sums=[(base + wb * b, wb, block_sum)
                                    for b in range(n_blocks)])
            fresh.stop()
            shutil.rmtree(wal_dir, ignore_errors=True)
        else:
            tm.stop()
        return {
            "workload": self.name, "backend": backend, "tm": backend,
            "variant": spec.variant, "seed": seed,
            "write_words": wb, "n_blocks": n_blocks,
            "durable": bool(p["durable"]), "grouped": grouped,
            "commit_groups": counters.get("groups", 0),
            "grouped_members": counters.get("grouped_members", 0),
            "updates_per_sec": counters["updates"] / dt,
            "failed_updates": counters["failed_updates"],
            "checks_per_sec": counters["checks"] / dt,
            "failed_checks": counters["failed_checks"],
            "violations": (counters["violations"] + len(post)
                           + len(drill_failures)),
            "post_invariant_failures": post,
            "restart_drill_failures": drill_failures,
            "wal_records_replayed": replayed,
            "wal_stats": wal_stats,
            "mode_transitions": stats.get("mode_transitions", 0),
            "stm_stats": stats,
        }


WORKLOADS = {w.name: w for w in (LongReadWorkload(), RWMixWorkload(),
                                 ShardScaleWorkload(), StructRQWorkload(),
                                 ServingWorkload(),
                                 ReliabilityWorkload(),
                                 DurabilityWorkload())}
