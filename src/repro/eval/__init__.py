"""repro.eval — the paper-figure evaluation subsystem.

Reproduces the paper's long-running-read experiments across every
registered backend, with the batched snapshot-read path
(``Txn.read_bulk`` / ``kernels/gather_read.py``) as the measurement
surface, so the numbers reflect the TM algorithm rather than the Python
interpreter:

    python -m repro.eval --workload longread            # the headline
    python -m repro.eval --workload structrq --quick    # CI smoke

    from repro.eval import run_eval
    rows, path = run_eval("longread", seed=3)

Workload families live in ``workloads.py`` (longread / rwmix /
structrq / serving), the thread/warmup machinery in ``driver.py``, and the
normalized ``{meta, rows}`` results schema in ``results.py`` — shared
with ``benchmarks/run.py`` so everything under ``results/`` carries the
same ``{git_sha, seed, backends, mode_transitions}`` meta block.
See BENCHMARKS.md for how each experiment maps to a paper figure.
"""
from repro.eval.driver import (  # noqa: F401
    durability_headline,
    longread_headline,
    run_eval,
    serving_headline,
    time_trial,
)
from repro.eval.results import save_results  # noqa: F401
from repro.eval.workloads import (  # noqa: F401
    DEFAULT_BACKENDS,
    UNVERSIONED,
    WORKLOADS,
    TrialSpec,
)

__all__ = [
    "DEFAULT_BACKENDS", "TrialSpec", "UNVERSIONED", "WORKLOADS",
    "durability_headline", "longread_headline", "run_eval",
    "save_results", "serving_headline", "time_trial",
]
