"""Unified eval driver: any workload x any registered backend.

``run_eval`` is the one entry point: it expands a workload's variants,
runs each against each backend with per-thread workers, a warmup window
(excluded from measurement) and fine-grained GIL switching, and writes
the rows through ``repro.eval.results`` — one normalized file per
workload instead of ad-hoc per-figure JSON.

    from repro.eval import run_eval
    rows, path = run_eval("longread", quick=True)

Thread accounting: each worker owns a private counter dict (no locks on
the hot path); the driver snapshots counters at the warmup boundary and
reports deltas over the measured window, so throughput excludes JIT/
heuristic warmup (mode transitions triggered during warmup do persist —
that is the steady state the paper measures).
"""
from __future__ import annotations

import sys
import threading
import time
from collections import defaultdict
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.eval.results import save_results
from repro.eval.workloads import (
    DEFAULT_BACKENDS,
    UNVERSIONED,
    WORKLOADS,
    TrialSpec,
)

__all__ = ["run_eval", "time_trial", "longread_headline",
           "rwmix_headline", "shardscale_headline", "structrq_headline",
           "serving_headline", "reliability_headline",
           "durability_headline"]


def time_trial(workers: Sequence[Callable], spec: TrialSpec,
               switch_interval: float = 2e-5) -> Tuple[Dict, float]:
    """Run ``workers[i](stop_event, counters[i])`` threads for one trial.

    Returns ``(counters, measured_seconds)`` where ``counters`` holds the
    per-key deltas accumulated AFTER the warmup window — except
    ``violations``, which is reported as the RAW total: a torn snapshot
    during warmup is still a correctness failure, never a number to
    warm up past.  The switch interval is dropped so updaters genuinely
    interleave into long reads (without it an entire scan often runs
    between two GIL switches and the paper's contention disappears into
    scheduler artifacts).
    """
    old_si = sys.getswitchinterval()
    sys.setswitchinterval(switch_interval)
    stop = threading.Event()
    counters = [defaultdict(int) for _ in workers]
    threads = [threading.Thread(target=w, args=(stop, c), daemon=True)
               for w, c in zip(workers, counters)]
    try:
        [t.start() for t in threads]
        time.sleep(spec.warmup_s)
        baseline = [dict(c) for c in counters]
        t0 = time.perf_counter()
        time.sleep(spec.duration_s)
        dt = time.perf_counter() - t0
    finally:
        stop.set()
        [t.join() for t in threads]
        sys.setswitchinterval(old_si)
    total: Dict[str, int] = defaultdict(int)
    for c, base in zip(counters, baseline):
        for k, v in c.items():
            total[k] += v if k == "violations" else v - base.get(k, 0)
    return total, dt


def run_eval(workload: str, backends: Optional[Sequence[str]] = None,
             seed: int = 0, quick: bool = False,
             out_dir: Optional[str] = None, save: bool = True,
             progress: Optional[Callable[[Dict], None]] = None,
             ) -> Tuple[List[Dict], Optional[str]]:
    """Run one workload family across backends; returns (rows, path).

    ``backends=None`` uses the workload's default set (all six registered
    backends unless the workload narrows it); ``quick=True`` shrinks
    variants and durations to a CI smoke.  ``progress`` is called with
    each finished row (the CLI prints a table line from it).
    """
    try:
        w = WORKLOADS[workload]
    except KeyError:
        raise ValueError(
            f"unknown workload {workload!r}; available: "
            f"{sorted(WORKLOADS)}") from None
    names = list(backends or getattr(w, "default_backends",
                                     DEFAULT_BACKENDS))
    rows: List[Dict] = []
    for spec in w.variants(quick):
        for backend in names:
            row = w.run_trial(backend, spec, seed)
            rows.append(row)
            if progress is not None:
                progress(row)
    path = None
    if save:
        path = save_results(workload, rows, seed, out_dir=out_dir,
                            extra_meta={"workload": workload,
                                        "quick": quick})
    return rows, path


def longread_headline(rows: List[Dict]) -> Dict:
    """The paper's central claim, extracted from longread rows.

    At the LARGEST scan size: does Multiverse's completed-scan throughput
    exceed every unversioned baseline's?  Returns the comparison (the CLI
    prints it; BENCHMARKS.md documents the expected shape).
    """
    sizes = {r["scan_size"] for r in rows if "scan_size" in r}
    if not sizes:
        return {}
    largest = max(sizes)
    at = {r["backend"]: r["scans_per_sec"] for r in rows
          if r.get("scan_size") == largest}
    mv = at.get("multiverse", 0.0)
    baselines = {b: at[b] for b in UNVERSIONED if b in at}
    return {
        "scan_size": largest,
        "multiverse_scans_per_sec": mv,
        "baseline_scans_per_sec": baselines,
        "multiverse_wins": bool(baselines) and all(
            mv > v for v in baselines.values()),
    }


def rwmix_headline(rows: List[Dict]) -> Dict:
    """The paper's SECOND headline claim, extracted from rwmix rows.

    At the LARGEST write-set size: does Multiverse's committed-update
    throughput stay within 2x of the BEST unversioned baseline's, with
    zero consistency violations?  (Unversioned STMs are supposed to win
    the update-heavy regime; Multiverse matching them shows the
    versioning machinery is pay-as-you-go.)  Returns the comparison
    (the CLI prints it; BENCHMARKS.md documents the expected shape).
    """
    sizes = {r["write_words"] for r in rows if "write_words" in r}
    if not sizes:
        return {}
    largest = max(sizes)
    at = {r["backend"]: r["updates_per_sec"] for r in rows
          if r.get("write_words") == largest}
    mv = at.get("multiverse", 0.0)
    baselines = {b: at[b] for b in UNVERSIONED if b in at}
    best = max(baselines.values()) if baselines else 0.0
    ratio = mv / best if best > 0 else 0.0
    return {
        "write_words": largest,
        "multiverse_updates_per_sec": mv,
        "baseline_updates_per_sec": baselines,
        "best_unversioned": best,
        "ratio_vs_best": ratio,
        "within_2x": bool(baselines) and ratio >= 0.5,
        # the MULTIVERSE claim's own violations — a baseline backend's
        # torn snapshot must not print as multiverse's; the CLI's global
        # exit gate still sums every row's violations separately
        "violations": sum(r.get("violations", 0) for r in rows
                          if r.get("backend") == "multiverse"),
    }


def shardscale_headline(rows: List[Dict]) -> Dict:
    """The SHARDING claim, extracted from shardscale rows.

    Same total heap words, same two disjoint-block updaters: does the
    2-shard store's committed-update throughput reach >=1.6x the
    1-shard store's?  At one shard both updaters share a commit clock
    and every interleaved publish forces an abort/retry; at two shards
    the per-shard clocks make the same workload conflict-free, so the
    ratio measures exactly the waste the two-level clock removes.  The
    shard==1 row's ``parity_ok`` (bit-identical dual-drive vs mvstore)
    must hold for the comparison to mean anything, and violations must
    be zero — a speedup bought with torn snapshots is a bug, not a
    result.
    """
    at = {r["n_shards"]: r for r in rows
          if r.get("backend") == "shardstore" and "n_shards" in r}
    if 1 not in at or 2 not in at:
        return {}
    base = at[1]["updates_per_sec"]
    ratio = at[2]["updates_per_sec"] / base if base > 0 else 0.0
    violations = sum(r.get("violations", 0) for r in at.values())
    return {
        "updates_per_sec": {n: r["updates_per_sec"]
                            for n, r in sorted(at.items())},
        "failed_updates": {n: r["failed_updates"]
                           for n, r in sorted(at.items())},
        "ratio_2_shards": ratio,
        "scales_1_6x": ratio >= 1.6,
        "parity_ok": bool(at[1].get("parity_ok")),
        "violations": violations,
        "holds": bool(ratio >= 1.6 and at[1].get("parity_ok")
                      and violations == 0),
    }


def serving_headline(rows: List[Dict]) -> Dict:
    """The SERVING claim, extracted from serving rows.

    At the HIGHEST target QPS: does multiverse (Mode-U ring) sustain
    the offered load — >=95% of offered requests completed, nothing
    shed, zero torn reads — while at least one baseline policy shows
    measurably degraded latency (p99 or p50 inflated vs multiverse)
    or abort-driven shedding (requests failed after repeated Mode-Q
    snapshot aborts, or shed by admission control because aborts ate
    the slot throughput)?  NaN percentiles (a baseline that starved
    outright, completing nothing) count as degraded via its
    failed/shed counters, never as a pass.
    """
    targets = {r["target_qps"] for r in rows if "target_qps" in r}
    if not targets:
        return {}
    top = max(targets)
    at = {r["backend"]: r for r in rows if r.get("target_qps") == top}
    mv = at.get("multiverse")
    if mv is None:
        return {}
    offered = max(mv.get("offered", 0), 1)
    sustained = (mv["completed"] >= 0.95 * offered
                 and mv["shed"] == 0 and mv["failed_aborts"] == 0
                 and mv["violations"] == 0)
    baselines: Dict[str, Dict] = {}
    for b, r in at.items():
        if b == "multiverse":
            continue
        p99_ratio = (r["p99_ms"] / mv["p99_ms"]
                     if mv["p99_ms"] > 0 else float("nan"))
        p50_ratio = (r["p50_ms"] / mv["p50_ms"]
                     if mv["p50_ms"] > 0 else float("nan"))
        degraded = bool(p99_ratio >= 1.25 or p50_ratio >= 1.2
                        or r["failed_aborts"] > 0 or r["shed"] > 0)
        baselines[b] = {
            "qps": r["qps"], "p50_ms": r["p50_ms"],
            "p99_ms": r["p99_ms"], "p99_ratio": p99_ratio,
            "snapshot_aborts": r["snapshot_aborts"],
            "failed_aborts": r["failed_aborts"], "shed": r["shed"],
            "mixed_version_requests": r["mixed_version_requests"],
            "degraded": degraded,
        }
    return {
        "target_qps": top,
        "multiverse_qps": mv["qps"],
        "multiverse_p50_ms": mv["p50_ms"],
        "multiverse_p99_ms": mv["p99_ms"],
        "multiverse_sustains": sustained,
        "violations": mv["violations"],
        "baselines": baselines,
        "baseline_degraded": any(d["degraded"]
                                 for d in baselines.values()),
    }


def reliability_headline(rows: List[Dict]) -> Dict:
    """The crash-recovery claim, extracted from reliability rows.

    Per backend, compare the faulted variant (a worker killed
    mid-publish every ~kill_every commits, recovered, re-admitted)
    against the fault-free twin: recovery must actually have run
    (kills > 0, every kill recovered), the trial must stay within 2x of
    fault-free throughput (ratio >= 0.5), and violations — torn checker
    reads AND post-trial invariant failures — must be zero.  The CLI
    exits non-zero on any violation; ``holds`` summarizes the rest.
    """
    per: Dict[str, Dict] = {}
    for r in rows:
        if "kill_every" not in r:
            continue
        slot = per.setdefault(r["backend"], {})
        key = "faulted" if r["kill_every"] else "nofault"
        slot[key] = r
    out: Dict[str, Dict] = {}
    for backend, slot in per.items():
        nf, f = slot.get("nofault"), slot.get("faulted")
        if nf is None or f is None:
            continue
        base = nf["updates_per_sec"]
        ratio = f["updates_per_sec"] / base if base > 0 else 0.0
        violations = nf["violations"] + f["violations"]
        out[backend] = {
            "kill_every": f["kill_every"],
            "kills": f["kills"],
            "recoveries": f["recoveries"],
            "rolled_forward": f["rolled_forward"],
            "rolled_back": f["rolled_back"],
            "nofault_updates_per_sec": base,
            "faulted_updates_per_sec": f["updates_per_sec"],
            "ratio_vs_nofault": ratio,
            "violations": violations,
            "holds": bool(f["kills"] > 0
                          and f["recoveries"] == f["kills"]
                          and ratio >= 0.5 and violations == 0),
        }
    return out


def durability_headline(rows: List[Dict]) -> Dict:
    """The durable-commit claim, extracted from durability rows.

    Per backend, compare durable variants (fsync'd WAL on the commit
    path + end-of-trial restart drill) against their in-memory twins.
    The gate runs on the GROUP-COMMIT pair when the backend actually
    fused groups — that is the amortized configuration the durability
    layer is designed around (one journal fsync per disjoint batch);
    the solo pair is reported alongside as ``solo_ratio_vs_inmem``, the
    unamortized fsync-per-commit tax.  ``holds`` requires the gated
    ratio >= 0.5, a restart drill that replayed records into a fresh
    engine, and zero violations — torn checker reads, post-trial
    invariant failures AND restart-drill failures — across all four
    variants.
    """
    per: Dict[str, Dict] = {}
    for r in rows:
        if "durable" not in r or r.get("workload") != "durability":
            continue
        per.setdefault(r["backend"], {})[r["variant"]] = r
    out: Dict[str, Dict] = {}
    for backend, slot in per.items():
        im, du = slot.get("inmem"), slot.get("durable")
        img, dug = slot.get("inmem-group"), slot.get("durable-group")
        solo_ratio = None
        if im is not None and du is not None and \
                im["updates_per_sec"] > 0:
            solo_ratio = du["updates_per_sec"] / im["updates_per_sec"]
        # gate on the group pair when it genuinely grouped; otherwise
        # (backend without a fused path, or group rows absent) the solo
        # pair is all there is
        use_group = (img is not None and dug is not None
                     and dug.get("grouped_members", 0) > 0
                     and img["updates_per_sec"] > 0)
        gate_im, gate_du = (img, dug) if use_group else (im, du)
        if gate_im is None or gate_du is None:
            continue
        base = gate_im["updates_per_sec"]
        ratio = gate_du["updates_per_sec"] / base if base > 0 else 0.0
        violations = sum(r["violations"] for r in slot.values())
        replayed = gate_du["wal_records_replayed"]
        out[backend] = {
            "gated_on": "group" if use_group else "solo",
            "inmem_updates_per_sec": base,
            "durable_updates_per_sec": gate_du["updates_per_sec"],
            "ratio_vs_inmem": ratio,
            "solo_ratio_vs_inmem": solo_ratio,
            "wal_records_replayed": replayed,
            "fsyncs": gate_du.get("wal_stats", {}).get("fsyncs", 0),
            "commit_groups": gate_du.get("commit_groups", 0),
            "violations": violations,
            "holds": bool(ratio >= 0.5 and violations == 0
                          and replayed > 0),
        }
    return out


def structrq_headline(rows: List[Dict]) -> Dict:
    """Struct long reads vs equivalent-size array scans, per structure.

    Each structrq row carries a quiescent single-thread reference pair
    (`rq_solo_per_sec` vs `arrayscan_per_sec` over the SAME word count
    on the same backend+heap); the headline extracts Multiverse's ratio
    per structure and whether it lands within 5x of the flat scan —
    pointer-chasing long reads used to be interpreter-bound, the
    frontier-at-a-time traversal is what closes the gap.  Returns
    ``{structure: {...}}`` (the CLI prints it; BENCHMARKS.md documents
    the expected shape).
    """
    out: Dict[str, Dict] = {}
    for r in rows:
        if r.get("backend") == "multiverse" and "rq_vs_scan" in r:
            ratio = r["rq_vs_scan"]
            out[r["structure"]] = {
                "rq_words": r["rq_words"],
                "rq_solo_per_sec": r["rq_solo_per_sec"],
                "arrayscan_per_sec": r["arrayscan_per_sec"],
                "rq_vs_scan": ratio,
                "within_5x": ratio >= 0.2,
            }
    return out
