"""Normalized results schema for every paper-figure experiment.

One writer for everything under ``results/``: the eval subsystem
(``python -m repro.eval``) and the legacy figure benches
(``benchmarks/run.py``) both emit

    {
      "meta": {
        "schema_version": 1,
        "bench":            experiment name ("eval_longread", "fig6", ...),
        "git_sha":          short SHA of the tree that produced the file,
        "seed":             RNG seed threaded into every workload,
        "backends":         sorted backend names appearing in rows,
        "mode_transitions": {row label -> mode-counter advances},
        ...                 experiment-specific extras (workload params)
      },
      "rows": [ {<flat measurement row>}, ... ]
    }

so a results file names exactly what it measured and can be re-run
bit-for-bit (`BENCHMARKS.md` documents the row schemas per experiment).
Every row that came from a TM run carries the normalized ``stm_stats``
dict (``repro.core.stats_schema``) and its ``backend`` name; the meta
block is DERIVED from the rows, so it can never drift from them.
"""
from __future__ import annotations

import json
import os
import subprocess
from typing import Dict, List, Optional

SCHEMA_VERSION = 1

#: default output directory (env-overridable for CI / scratch runs)
RESULTS_DIR = os.environ.get("REPRO_RESULTS_DIR", "results")


def git_sha() -> str:
    """Short SHA of the current tree, or "unknown" outside a checkout."""
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=5,
        ).stdout.strip() or "unknown"
    except Exception:  # noqa: BLE001 - sandboxed/bare checkouts
        return "unknown"


def _row_label(r: Dict) -> str:
    """Unique-enough row label: backend/variant (or workload), so one
    backend's rows across a variant ladder don't collide in the meta."""
    tm = str(r.get("tm", r.get("backend", "?")))
    qualifier = r.get("variant", r.get("workload"))
    if qualifier and str(qualifier) != tm:
        return f"{tm}/{qualifier}"
    return tm


def build_meta(bench: str, rows: List[Dict], seed: int,
               extra: Optional[Dict] = None) -> Dict:
    """Derive the meta block from the rows (single source of truth)."""
    meta: Dict = {
        "schema_version": SCHEMA_VERSION,
        "bench": bench,
        "git_sha": git_sha(),
        "seed": seed,
        "backends": sorted({r["backend"] for r in rows
                            if isinstance(r, dict) and "backend" in r}),
        "mode_transitions": {
            _row_label(r): r["mode_transitions"]
            for r in rows
            if isinstance(r, dict) and "mode_transitions" in r},
    }
    if extra:
        meta.update(extra)
    return meta


def save_results(bench: str, rows: List[Dict], seed: int,
                 out_dir: Optional[str] = None,
                 extra_meta: Optional[Dict] = None,
                 prefix: str = "eval") -> str:
    """Write ``{meta, rows}`` to ``<out_dir>/<prefix>_<bench>.json``.

    Returns the path written.  ``prefix="bench"`` keeps the historical
    ``bench_fig6.json`` names for the figure benches.
    """
    out_dir = out_dir or RESULTS_DIR
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{prefix}_{bench}.json")
    payload = {"meta": build_meta(bench, rows, seed, extra_meta),
               "rows": rows}
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path
