"""Write-ahead commit log: the durable twin of ``publish_started``.

PR 8's crash recovery keys every roll-forward/roll-back decision on
``TxnDescriptor.publish_started`` — process memory.  A real ``kill -9``
loses it, and with it the committed prefix.  This module makes the
commit record durable with the classic two-marker WAL protocol, shaped
to fit the existing pipelines:

  * PREPARE — the serialized write-set (tid, addrs, values, pinned
    clock(s), epoch + shard for the sharded store), appended BEFORE the
    claim/scatter phase.  A prepare alone decides nothing: a crash (or
    an ordinary abort) that never reaches DECIDE rolls BACK by simply
    not replaying the record.
  * DECIDE — appended + fsync'd at the exact instant ``publish_started``
    flips True, BEFORE the first heap mutation (the write-ahead
    invariant).  Group commit amortizes: one DECIDE frame carrying every
    surviving member's lsn, one fsync per group — the same batching the
    fused megakernel gives the publish itself.  The cross-shard
    ``EpochRecord`` is one prepare per write shard + one group DECIDE,
    so the epoch is all-or-nothing across restarts too.
  * COMPLETE — buffered, informational: replay is idempotent either
    way, but decided-without-COMPLETE is what ``recover_from_wal``
    reports as rolled forward.

Frames are length- and CRC-framed (``MWAL | len | crc32 | payload``), so
a torn tail — the frame a dying ``write()`` cut in half — is detected
and dropped, never misparsed; segments roll at ``segment_bytes`` and a
``checkpoint`` writes an atomic base image (``save_checkpoint``'s
tmp + ``os.replace`` idiom) that lets old segments be reclaimed.

``recover_from_wal`` rebuilds a FRESH target (word engine, MVStore
handle or sharded store — all in-memory state lost) by replaying every
decided record in lsn order, then runs the existing owner-scan /
torn-row sweep so the caller's ``check_*_invariants`` passes.  Redo is
whole-record and idempotent: a partial-lane kernel fault that scattered
half the lanes is healed by re-scattering all of them.

Values are int64 (this is the numeric-heap layer — parameter blocks and
the int benchmarks); a non-numeric heap cannot go durable and
``append_prepare`` raises rather than silently logging garbage.
"""
from __future__ import annotations

import dataclasses
import os
import struct
import threading
import zlib
from typing import Any, Iterable, List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["WriteAheadLog", "WalRecord", "scan_dir", "attach_wal",
           "recover_from_wal"]

MAGIC = b"MWAL"
_FRAME = struct.Struct("<4sII")            # magic, payload len, crc32
_PREP = struct.Struct("<BQqiqHI")          # type, lsn, tid, shard, epoch,
                                           #   n_clocks, n_writes
_MARK = struct.Struct("<BQ")               # type, lsn   (COMPLETE / BASE)
_DEC = struct.Struct("<BI")                # type, n_lsns

REC_PREPARE = 1
REC_DECIDE = 2
REC_COMPLETE = 3
REC_BASE = 4

_SEG_FMT = "wal-%08d.seg"
_BASE_FMT = "base-%016d.npz"


@dataclasses.dataclass
class WalRecord:
    """One prepared commit as scanned back from the segment files."""

    lsn: int
    tid: int
    shard: int                  # -1 = unsharded; else owning shard id
    epoch: int                  # -1 = not a cross-shard epoch member
    clocks: Tuple[int, ...]     # pinned clock(s) at prepare time
    addrs: np.ndarray           # int64 write-set addresses
    values: np.ndarray          # int64 write-set values
    decided: bool = False
    completed: bool = False


def _prepare_frame(lsn: int, tid: int, addrs, values, clocks,
                   epoch: int, shard: int) -> bytes:
    a = np.asarray(addrs if hasattr(addrs, "__len__") else list(addrs),
                   dtype=np.int64)
    try:
        v = np.asarray(values if hasattr(values, "__len__")
                       else list(values), dtype=np.int64)
    except (TypeError, ValueError) as e:
        raise TypeError(
            "WAL records are int64: durable mode needs a numeric heap "
            f"({e})") from e
    if v.shape != a.shape:
        raise ValueError(f"addrs/values length mismatch: "
                         f"{a.shape} vs {v.shape}")
    c = np.asarray(tuple(clocks), dtype=np.int64)
    payload = (_PREP.pack(REC_PREPARE, lsn, int(tid), int(shard),
                          int(epoch), c.size, a.size)
               + c.tobytes() + a.astype("<i8").tobytes()
               + v.astype("<i8").tobytes())
    return _frame(payload)


def _frame(payload: bytes) -> bytes:
    return _FRAME.pack(MAGIC, len(payload), zlib.crc32(payload)) + payload


# fdatasync skips the mtime flush but (per POSIX) still flushes the size
# change an append needs for the data to be retrievable after a crash —
# the cheapest call that keeps the decide durable.
_fdatasync = getattr(os, "fdatasync", os.fsync)


class WriteAheadLog:
    """Append-only, fsync'd, segmented commit log.

    Thread-safe (one internal lock — appends from concurrent commit
    pipelines interleave whole frames, never bytes).  Reopening an
    existing directory continues the lsn sequence in a FRESH segment, so
    a torn tail left by the previous process never gets appended past.
    """

    def __init__(self, path: str, *, segment_bytes: int = 4 << 20,
                 sync: bool = True, group_sync: bool = False):
        self.dir = str(path)
        os.makedirs(self.dir, exist_ok=True)
        self.segment_bytes = int(segment_bytes)
        self.sync = bool(sync)
        self._lock = threading.RLock()
        # group-sync state: appends bump _append_seq under _lock; the
        # fsync that settles durability runs under _sync_lock WITHOUT
        # _lock, so concurrent committers keep appending while the disk
        # works, and any decide that fsync covered piggybacks
        # (_synced_seq only ever grows).  Lock order: _sync_lock before
        # _lock, never the reverse.
        self._sync_lock = threading.Lock()
        self._append_seq = 0
        self._synced_seq = 0
        self._cv = threading.Condition(self._lock)
        self.counters = {"records": 0, "decides": 0, "fsyncs": 0,
                         "bytes": 0, "segments": 0}
        segs = self._segments()
        self._seg_idx = (segs[-1][0] + 1) if segs else 0
        self._next_lsn = 0
        if segs:
            recs, _torn, base = scan_dir(self.dir)
            floor = base[0] if base is not None else 0
            self._next_lsn = max([floor] + [r.lsn + 1 for r in recs])
        self._f = None
        self._open_segment()
        # group_sync: a dedicated syncer thread owns every fdatasync;
        # committers append, then sleep on the condvar until the
        # syncer's next cycle covers their frame.  The disk pipeline
        # runs back-to-back while committers' Python overlaps it — the
        # throughput shape of group commit without batching the commits
        # themselves.
        self._syncer = None
        self._syncer_stop = False
        if group_sync and self.sync:
            self._syncer = threading.Thread(
                target=self._sync_loop, name="wal-syncer", daemon=True)
            self._syncer.start()

    # -- segment bookkeeping ------------------------------------------
    def _segments(self) -> List[Tuple[int, str]]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("wal-") and name.endswith(".seg"):
                out.append((int(name[4:-4]), os.path.join(self.dir, name)))
        return sorted(out)

    def _open_segment(self) -> None:
        if self._f is not None:
            self._f.close()
        path = os.path.join(self.dir, _SEG_FMT % self._seg_idx)
        self._seg_idx += 1
        self._f = open(path, "ab")
        self.counters["segments"] += 1

    def _maybe_roll(self) -> None:
        # only ever between whole frames — a roll can't tear a record
        if self._f.tell() >= self.segment_bytes:
            self.flush(fsync=self.sync)
            self._open_segment()

    # -- appends -------------------------------------------------------
    def append_prepare(self, tid: int, addrs, values, *,
                       clocks: Sequence[int] = (), epoch: int = -1,
                       shard: int = -1) -> int:
        """Buffered PREPARE (call BEFORE the claim); returns the lsn."""
        with self._lock:
            lsn = self._next_lsn
            self._next_lsn += 1
            buf = _prepare_frame(lsn, tid, addrs, values, clocks,
                                 epoch, shard)
            self._f.write(buf)
            self.counters["records"] += 1
            self.counters["bytes"] += len(buf)
            return lsn

    def append_prepare_group(self, recs: Iterable[tuple]) -> List[int]:
        """Batched PREPAREs — one buffered write for a whole commit
        group.  ``recs`` items: ``(tid, addrs, values, clocks, epoch,
        shard)``."""
        with self._lock:
            frames, lsns = [], []
            for tid, addrs, values, clocks, epoch, shard in recs:
                lsn = self._next_lsn
                self._next_lsn += 1
                frames.append(_prepare_frame(lsn, tid, addrs, values,
                                             clocks, epoch, shard))
                lsns.append(lsn)
            if frames:
                buf = b"".join(frames)
                self._f.write(buf)
                self.counters["records"] += len(frames)
                self.counters["bytes"] += len(buf)
            return lsns

    def append_decide(self, lsn: int) -> None:
        """The durable commit record: DECIDE + fsync, call at the exact
        point ``publish_started`` flips True (before any heap write)."""
        self.append_decide_group((lsn,))

    def append_decide_group(self, lsns: Sequence[int]) -> None:
        """One DECIDE frame for a whole group, made durable by a
        COALESCED sync — group commit across transactions AND threads.

        The frame is appended and flushed under the append lock; the
        blocking ``fdatasync`` then runs under a separate sync lock with
        the append lock RELEASED, so other committers keep appending
        while the disk works.  Whichever committer reaches the sync lock
        first syncs everything flushed so far; a committer whose frame
        that sync already covered returns without touching the disk.
        Either way this method never returns before the caller's DECIDE
        is durable — the write-ahead invariant is untouched, only the
        number of device flushes shrinks.
        """
        if not lsns:
            return
        with self._lock:
            payload = (_DEC.pack(REC_DECIDE, len(lsns))
                       + np.asarray(lsns, "<u8").tobytes())
            buf = _frame(payload)
            self._f.write(buf)
            self._f.flush()
            self.counters["decides"] += len(lsns)
            self.counters["bytes"] += len(buf)
            self._append_seq += 1
            my_seq = self._append_seq
            if not self.sync:
                self._maybe_roll()
                return
            if self._syncer is not None:
                # wake the syncer, then sleep (lock released) until its
                # fsync covers this frame — the wait timeout is only a
                # lost-wakeup safety net
                self._cv.notify_all()
                while self._synced_seq < my_seq:
                    self._cv.wait(0.05)
                return
        if self._synced_seq >= my_seq:   # a peer's fsync covered us
            return
        with self._sync_lock:
            if self._synced_seq >= my_seq:
                return
            self._sync_cycle()

    def _sync_cycle(self) -> bool:
        """One durability step: flush + fdatasync everything appended so
        far, then publish the new synced frontier.  Caller holds
        ``_sync_lock``; the blocking fdatasync runs with the append lock
        RELEASED so committers keep appending while the disk works."""
        with self._lock:
            if self._append_seq == self._synced_seq:
                return False
            self._f.flush()
            target = self._append_seq
            fd = self._f.fileno()
        _fdatasync(fd)
        with self._lock:
            self.counters["fsyncs"] += 1
            self._synced_seq = target
            self._maybe_roll()           # rolls only under _sync_lock,
                                         # so fd above is never stale
            self._cv.notify_all()
        return True

    def _sync_loop(self) -> None:
        while True:
            with self._sync_lock:
                did = self._sync_cycle()
            with self._cv:
                if self._syncer_stop and \
                        self._append_seq == self._synced_seq:
                    return
                if not did and not self._syncer_stop:
                    self._cv.wait(0.05)

    def append_complete(self, lsn: int) -> None:
        """Buffered COMPLETE marker (publish finished; replay-optional)."""
        with self._lock:
            buf = _frame(_MARK.pack(REC_COMPLETE, lsn))
            self._f.write(buf)
            self.counters["bytes"] += len(buf)
            if not self.sync:
                # sync mode rolls in the decide path (under _sync_lock);
                # rolling here could close the fd out from under a
                # concurrent leader's fdatasync
                self._maybe_roll()

    # -- durability / lifecycle ---------------------------------------
    def flush(self, fsync: Optional[bool] = None) -> None:
        with self._lock:
            self._f.flush()
            if self.sync if fsync is None else fsync:
                os.fsync(self._f.fileno())
                self.counters["fsyncs"] += 1

    def checkpoint(self, heap_values, clock: int) -> int:
        """Write an atomic base image; records below the returned floor
        lsn no longer need replaying and their segments are reclaimed.

        Same publish idiom as ``checkpoint/snapshotter.save_checkpoint``:
        write to a tmp name, fsync, ``os.replace`` — a crash mid-
        checkpoint leaves only a tmp file the scan ignores.
        """
        with self._sync_lock, self._lock:
            floor = self._next_lsn
            final = os.path.join(self.dir, _BASE_FMT % floor)
            tmp = final + ".tmp"
            with open(tmp, "wb") as f:
                np.savez(f, heap=np.asarray(heap_values, np.int64),
                         clock=np.int64(clock), floor=np.int64(floor))
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, final)
            buf = _frame(_MARK.pack(REC_BASE, floor))
            self._f.write(buf)
            self.flush(fsync=self.sync)
            # reclaim: everything below the floor is in the base image
            cur = self._f.name
            for _idx, path in self._segments():
                if path != cur:
                    os.unlink(path)
            for name in os.listdir(self.dir):
                if (name.startswith("base-") and name.endswith(".npz")
                        and name != os.path.basename(final)):
                    os.unlink(os.path.join(self.dir, name))
            return floor

    def stats(self) -> dict:
        out = dict(self.counters)
        out["next_lsn"] = self._next_lsn
        return out

    def close(self) -> None:
        if self._syncer is not None:
            with self._cv:
                self._syncer_stop = True
                self._cv.notify_all()
            self._syncer.join(timeout=5.0)
            self._syncer = None
        with self._sync_lock, self._lock:
            if self._f is not None:
                self.flush(fsync=self.sync)
                self._f.close()
                self._f = None

    def __enter__(self) -> "WriteAheadLog":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


# ---------------------------------------------------------------------------
# scan (restart path)
# ---------------------------------------------------------------------------


def _scan_segment(path: str, records: dict, decided: set,
                  completed: set) -> int:
    """Parse one segment; returns torn-tail bytes dropped (0 = clean).

    Stops at the first bad frame — a frame the dying process cut in
    half can only be the LAST thing written to the then-live segment, so
    everything after a failed length/CRC check is the tear.
    """
    with open(path, "rb") as f:
        data = f.read()
    off, n = 0, len(data)
    while off + _FRAME.size <= n:
        magic, ln, crc = _FRAME.unpack_from(data, off)
        if magic != MAGIC or off + _FRAME.size + ln > n:
            break
        payload = data[off + _FRAME.size: off + _FRAME.size + ln]
        if zlib.crc32(payload) != crc:
            break
        kind = payload[0]
        if kind == REC_PREPARE:
            (_k, lsn, tid, shard, epoch,
             n_clk, n_w) = _PREP.unpack_from(payload, 0)
            body = payload[_PREP.size:]
            clocks = np.frombuffer(body, "<i8", n_clk)
            a0 = n_clk * 8
            addrs = np.frombuffer(body, "<i8", n_w, a0)
            vals = np.frombuffer(body, "<i8", n_w, a0 + n_w * 8)
            records[lsn] = WalRecord(
                lsn=lsn, tid=tid, shard=shard, epoch=epoch,
                clocks=tuple(int(c) for c in clocks),
                addrs=addrs.astype(np.int64),
                values=vals.astype(np.int64))
        elif kind == REC_DECIDE:
            _k, cnt = _DEC.unpack_from(payload, 0)
            decided.update(
                int(x) for x in np.frombuffer(payload, "<u8", cnt,
                                              _DEC.size))
        elif kind == REC_COMPLETE:
            _k, lsn = _MARK.unpack_from(payload, 0)
            completed.add(int(lsn))
        # REC_BASE frames are advisory; the base image carries the floor
        off += _FRAME.size + ln
    return n - off


def scan_dir(path: str):
    """Scan a WAL directory.

    Returns ``(records, torn_bytes, base)`` — ``records`` is the
    lsn-ordered list of :class:`WalRecord` (``decided``/``completed``
    resolved), ``torn_bytes`` counts dropped torn-tail bytes, ``base``
    is ``(floor_lsn, heap, clock)`` from the newest checkpoint image or
    ``None``.  Records below the base floor are already in the image
    and are omitted.
    """
    records: dict = {}
    decided: set = set()
    completed: set = set()
    torn = 0
    segs = sorted(name for name in os.listdir(path)
                  if name.startswith("wal-") and name.endswith(".seg"))
    for name in segs:
        torn += _scan_segment(os.path.join(path, name), records,
                              decided, completed)
    base = None
    bases = sorted(name for name in os.listdir(path)
                   if name.startswith("base-") and name.endswith(".npz"))
    if bases:
        with np.load(os.path.join(path, bases[-1])) as z:
            base = (int(z["floor"]), np.asarray(z["heap"], np.int64),
                    int(z["clock"]))
    floor = base[0] if base is not None else 0
    out = []
    for lsn in sorted(records):
        if lsn < floor:
            continue
        r = records[lsn]
        r.decided = lsn in decided
        r.completed = lsn in completed
        out.append(r)
    return out, torn, base


# ---------------------------------------------------------------------------
# attach / recover
# ---------------------------------------------------------------------------


def attach_wal(target: Any, wal: WriteAheadLog) -> WriteAheadLog:
    """Point a substrate's commit pipeline at a WAL.

    Word engines (and their ``WordSubstrate`` wrappers), MVStore handles
    and sharded stores all grow a ``wal`` slot the pipelines check; the
    sharded store additionally tags each member shard so its records
    carry the shard id the replay routes by.
    """
    t = getattr(target, "raw", target)
    t.wal = wal
    if hasattr(t, "_shards"):
        for s, sh in enumerate(t._shards):
            sh.wal = wal
            sh.wal_shard = s
    return wal


def _plain_scatter(heap, addrs, values) -> None:
    # recovery-side scatter: NEVER routes through the commit pipeline's
    # fault points — replay must not re-fire the schedule that killed us
    sc = getattr(heap, "scatter", None)
    if sc is not None:
        sc(np.asarray(addrs, np.int64), values)
        return
    for a, v in zip(addrs, values):
        heap[int(a)] = v


def recover_from_wal(wal: Any, target: Any = None):
    """Replay the durable committed prefix into a fresh ``target``.

    ``wal`` is a :class:`WriteAheadLog` or a directory path.  ``target``
    is a word engine / ``WordSubstrate`` (replay scatters into its
    heap, floors its clock, then runs the owner-scan + torn-row sweep),
    an ``MVStoreHandle`` or ``ShardStoreHandle`` (replay re-drives each
    decided record through the exact publish path, suppressing re-
    logging), or ``None`` (scan only).  Returns a
    ``recovery.RecoveryReport`` whose WAL counters feed
    ``core.stats_schema.normalize_stats``:

      * ``wal_records_replayed`` — decided records redone (idempotent,
        whole-record: a partial-lane crash image is overwritten);
      * ``rolled_forward`` — tids of decided-but-not-COMPLETE records
        (the mid-publish crashes);
      * ``rolled_back``  — tids of prepared-but-undecided records
        (dropped: they never decided).
    """
    from repro.reliability.recovery import (RecoveryReport, repair_mirror)

    if isinstance(wal, WriteAheadLog):
        wal.flush(fsync=False)       # same-process restart drills
        path = wal.dir
    else:
        path = str(wal)
    records, torn, base = scan_dir(path)
    rep = RecoveryReport()
    rep.wal_torn_bytes = torn
    decided = [r for r in records if r.decided]
    for r in records:
        if not r.decided:
            rep.rolled_back.append(r.tid)
    t = getattr(target, "raw", target) if target is not None else None
    if t is None:
        for r in decided:
            rep.wal_records_replayed += 1
            if not r.completed:
                rep.rolled_forward.append(r.tid)
        return rep

    prev_wal = getattr(t, "wal", None)
    try:
        if prev_wal is not None:
            t.wal = None             # replay must not re-log itself
        if hasattr(t, "_shards"):
            _replay_shardstore(t, decided, rep)
        elif hasattr(t, "_publish_locked"):
            _replay_handle(t, decided, rep)
        else:
            _replay_engine(t, decided, base, rep)
            rep.repaired_mirror_rows = repair_mirror(t)
    finally:
        if prev_wal is not None:
            attach_wal(t, prev_wal)
    rep.apply_to(t)
    from repro.reliability import faultpoints as FP
    FP.reset_thread()
    return rep


def _replay_engine(eng, decided, base, rep) -> None:
    clock_floor = 0
    if base is not None:
        _floor, heap, clk = base
        if heap.size:
            _plain_scatter(eng.heap, np.arange(heap.size, dtype=np.int64),
                           heap.tolist())
        clock_floor = clk
    tids = set()
    for r in decided:
        _plain_scatter(eng.heap, r.addrs, r.values.tolist())
        rep.wal_records_replayed += 1
        tids.add(r.tid)
        if not r.completed:
            rep.rolled_forward.append(r.tid)
        if r.clocks:
            clock_floor = max(clock_floor, max(r.clocks))
    if eng.clock.load() < clock_floor:
        eng.clock.store(int(clock_floor))
    # owner-scan sweep: a fresh engine holds nothing, an in-place
    # restart drill may still hold the dead workers' claims
    for tid in sorted(tids):
        rep.released_locks += eng.release_thread_locks(int(tid))


def _replay_handle(handle, decided, rep) -> None:
    from repro.api.mvhandle import _MVCtx
    for r in decided:
        ctx = _MVCtx(max(int(r.tid), 0) % max(handle.n_threads, 1))
        ctx.read_only = False
        ctx.active = True
        ctx.write_buf = dict(zip(r.addrs.tolist(), r.values.tolist()))
        with handle._commit_lock:
            ctx.read_clock = int(handle._state.clock)
            handle._publish_locked(ctx, wal_log=False)
        ctx.active = False
        rep.wal_records_replayed += 1
        if not r.completed:
            rep.rolled_forward.append(r.tid)


def _replay_shardstore(store, decided, rep) -> None:
    epoch_floor = store._epoch.load()
    for r in decided:
        s = r.shard if r.shard >= 0 else 0
        _replay_handle(store._shards[s], [r], rep)
        if r.epoch >= 0:
            epoch_floor = max(epoch_floor, r.epoch)
    # cross-shard epochs replayed above are all-or-nothing by
    # construction: every member shares one group DECIDE, so either the
    # whole epoch is in `decided` or none of it is
    while store._epoch.load() < epoch_floor:
        store._epoch.increment()
    if store._epoch_seq.load() & 1:
        store._epoch_seq.increment()     # readers stop spinning
    store._epoch_inflight = None
