"""Crash recovery: reconstruct a consistent heap after a simulated crash.

The recovery rules fall out of where each pipeline's COMMIT RECORD sits
(``TxnDescriptor.publish_started``, set the instant a decided commit
starts publishing):

  * ``publish_started`` False — the transaction never decided (or
    decided to abort): roll BACK.  Buffered writes never touched the
    heap, so rollback is releasing whatever locks the attempt claimed;
    encounter-time writes restore from the undo log (the engine's
    ``_abort`` already knows every policy's rollback, including
    Multiverse's TBD-version unlink).
  * ``publish_started`` True — the commit decided and the heap (or the
    version list, for Multiverse: versioned readers can observe a
    cleared-TBD version before the locks drop) may already be visible:
    roll FORWARD.  Buffered pipelines redo the scatter from ``write_map``
    (idempotent — the locks are still held, nobody else wrote those
    words), Multiverse finishes publishing its version set, and the
    held locks release at a fresh clock tick — at/above the tick the
    crashed commit took, so readers only see a conservative version
    bump, never a torn value.

Either way the sweep finishes with ``release_thread_locks`` (claims the
crashed frame never recorded anywhere — TL2's commit-time claim list is
a lost local — are found by owner scan), a torn-row repair pass over the
PackedVLT mirror (odd seqlock -> reset the row to fail-closed empty),
and invariant checks the crash matrix asserts on.

``recover_handle`` is the MVStore twin: complete a crashed install from
``MVStoreHandle._inflight`` (the fused commit DONATED the old buffers,
so the in-flight state is the only copy of the store), truncate ring
timestamps past the durable clock, and verify a snapshot resolves at
every durable ring timestamp.  ``replay_from_checkpoint`` restores
training state from the newest manifest (the ``TrainSupervisor``
restore path lives here now).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Iterable, List, Optional, Sequence

import numpy as np

from repro.reliability import faultpoints as FP


#: the typed recovery counters every recover_* surfaces — their one
#: home is the shared stats schema (``as_stats`` projects onto them)
from repro.core.stats_schema import RECOVERY_STAT_KEYS  # noqa: E402,F401


@dataclasses.dataclass
class RecoveryReport:
    dead_tids: List[int] = dataclasses.field(default_factory=list)
    rolled_forward: List[int] = dataclasses.field(default_factory=list)
    rolled_back: List[int] = dataclasses.field(default_factory=list)
    released_locks: int = 0
    repaired_mirror_rows: int = 0
    truncated_ring_slots: int = 0
    completed_install: bool = False
    clock_before: int = 0
    clock_after: int = 0
    wal_records_replayed: int = 0
    wal_torn_bytes: int = 0

    # canonical satellite names for the sweep counters
    @property
    def locks_swept(self) -> int:
        return self.released_locks

    @property
    def torn_rows_repaired(self) -> int:
        return self.repaired_mirror_rows

    def as_stats(self) -> dict:
        """The report projected onto the shared stats schema keys —
        ``normalize_stats`` carries these through unchanged."""
        return {"rolled_forward": len(self.rolled_forward),
                "rolled_back": len(self.rolled_back),
                "locks_swept": self.released_locks,
                "torn_rows_repaired": self.repaired_mirror_rows,
                "wal_records_replayed": self.wal_records_replayed}

    def apply_to(self, target: Any) -> None:
        """Accumulate into the target's ``recovery_counters`` so its
        ``stats()`` (and thus ``normalize_stats``) surfaces recovery
        work instead of ad-hoc fields."""
        t = getattr(target, "raw", target)
        rc = getattr(t, "recovery_counters", None)
        if rc is not None:
            for k, v in self.as_stats().items():
                rc[k] += v

    def summary(self) -> str:
        return (f"recovered tids={self.dead_tids} "
                f"fwd={self.rolled_forward} back={self.rolled_back} "
                f"locks={self.released_locks} "
                f"mirror={self.repaired_mirror_rows} "
                f"ring={self.truncated_ring_slots} "
                f"wal={self.wal_records_replayed} "
                f"clock {self.clock_before}->{self.clock_after}")


def _unwrap(tm: Any) -> Any:
    """Accept an engine, a WordSubstrate, or anything with ``.raw``."""
    return getattr(tm, "raw", tm)


def locked_indices(locks) -> np.ndarray:
    """Every lock-table index with its locked bit set."""
    words = getattr(locks, "_words", None)
    if words is not None:
        return np.nonzero((words & 2) != 0)[0]
    return np.fromiter(
        (i for i in range(locks.size) if locks.read(i).locked),
        np.int64)


def _roll_forward(eng, d, commit_clock: int) -> None:
    """Finish a decided commit on behalf of a dead owner.

    The owner's locks are still held (that is WHY we can redo), so the
    scatter/publish below races nobody.
    """
    if d.write_map and not d.undo:
        # buffered: redo the write-back from the redo log (idempotent);
        # recovery never routes through heap_scatter — an installed
        # fault schedule must not inject into the repair itself
        from repro.reliability.wal import _plain_scatter
        wm = d.write_map
        addrs = np.fromiter(wm.keys(), np.int64, len(wm))
        _plain_scatter(eng.heap, addrs, list(wm.values()))
    if d.versioned_write_set:
        # Multiverse: finish clearing TBD marks / refreshing the mirror
        # at the recovery clock (>= the tick the crashed commit took)
        eng.policy._publish_versions(eng, d, commit_clock)
    retire = getattr(eng.policy, "_retire_bufs", None)
    if retire is not None:
        retire[d.tid].commit()
    d.stats["commits"] += 1
    d.active = False
    eng.policy.on_finish(eng, d)


def recover_engine(tm: Any, dead_tids: Sequence[int],
                   wal: Any = None) -> RecoveryReport:
    """Scan a word-level engine after a crash and restore consistency.

    ``dead_tids`` are the threads that died (every transaction they
    owned is orphaned) — MULTIPLE dead workers recover in this one
    sweep, including group-commit batch mates.  Safe to call with live
    threads quiesced — the crash matrix and the reliability workload
    both stop the world first, exactly like a real restart.

    ``wal`` (optional): the engine's attached WAL — a rolled-forward
    descriptor's durable record gets its COMPLETE marker here, so the
    journal reflects the finished publish.  (Replay stays idempotent
    without it; whole-process recovery is ``wal.recover_from_wal``.)
    """
    eng = _unwrap(tm)
    rep = RecoveryReport(dead_tids=sorted(int(t) for t in dead_tids))
    rep.clock_before = eng.clock.load()
    for tid in rep.dead_tids:
        d = eng.ctx(tid)
        if d.active:
            if d.publish_started:
                # one fresh tick serves as the recovered commit version
                cv = eng.clock.increment()
                _roll_forward(eng, d, cv)
                held = eng._held_by(tid)
                for idx in held:
                    eng.locks.unlock(int(idx), cv)
                rep.released_locks += len(held)
                rep.rolled_forward.append(tid)
                if wal is not None and d.wal_lsn is not None:
                    wal.append_complete(d.wal_lsn)
                    d.wal_lsn = None
            else:
                # the engine's abort already knows every policy's
                # rollback: undo restore, TBD unlink, deferred-clock bump
                eng._abort(d)
                rep.rolled_back.append(tid)
        # claims the descriptor never recorded (TL2's commit-time claim
        # list is a lost local): owner-scan sweep at a bumped clock
        rep.released_locks += eng.release_thread_locks(tid)
    rep.repaired_mirror_rows = repair_mirror(eng)
    rep.clock_after = eng.clock.load()
    rep.apply_to(eng)
    FP.reset_thread()
    return rep


def repair_mirror(tm: Any) -> int:
    """Reset torn PackedVLT mirror rows (odd per-row seqlock).

    A writer that died inside a seq bracket leaves the row permanently
    odd — readers already fail closed (scalar walk), but the row can
    never serve again.  Repair = empty the row and restore an even seq:
    fail-closed, and the next publish re-seeds it.
    Returns the number of rows repaired.

    LIVE-MODE SAFETY: mirror rows are keyed by lock index, and the
    writer discipline publishes only while holding that address lock —
    so a row that is odd while its lock word is HELD belongs to a live
    writer mid-bracket, not to the dead one, and must be skipped.  (The
    dead thread's locks were already swept before this runs.)
    """
    eng = _unwrap(tm)
    vlt = getattr(eng.policy, "vlt", None) if hasattr(eng, "policy") else None
    mirror = getattr(vlt, "mirror", None)
    if mirror is None:
        return 0
    from repro.core.vlt import EMPTY_TS
    torn = np.nonzero((mirror._seq & 1) != 0)[0]
    words = getattr(eng.locks, "_words", None)
    if words is not None and torn.size:
        torn = torn[(words[torn] & 2) == 0]      # skip live brackets
    for row in torn:
        mirror._addr[row] = mirror.NO_ADDR
        mirror._ts[row] = EMPTY_TS
        mirror._data[row] = 0
        mirror._seq[row] += 1
    return int(torn.size)


def check_engine_invariants(tm: Any, *,
                            expect_heap: Optional[np.ndarray] = None,
                            expect_sums: Optional[Iterable] = None,
                            clock_at_least: Optional[int] = None
                            ) -> List[str]:
    """Post-recovery invariants; returns human-readable violations.

    * lock table empty (no locked bits anywhere);
    * no torn PackedVLT mirror rows (every per-row seq even);
    * clock monotone (>= ``clock_at_least``);
    * heap equality (``expect_heap``) or block-sum conservation
      (``expect_sums``: iterable of ``(base, n, expected_sum)``).
    """
    eng = _unwrap(tm)
    out: List[str] = []
    held = locked_indices(eng.locks)
    if held.size:
        out.append(f"lock table not empty: {held.size} held "
                   f"(first {held[:8].tolist()})")
    vlt = getattr(eng.policy, "vlt", None) if hasattr(eng, "policy") else None
    mirror = getattr(vlt, "mirror", None)
    if mirror is not None:
        torn = int(((mirror._seq & 1) != 0).sum())
        if torn:
            out.append(f"{torn} torn PackedVLT mirror rows")
    if clock_at_least is not None and eng.clock.load() < clock_at_least:
        out.append(f"clock went backwards: {eng.clock.load()} "
                   f"< {clock_at_least}")
    if expect_heap is not None:
        buf = getattr(eng.heap, "_buf", None)
        got = (np.asarray(buf[:len(expect_heap)]) if buf is not None
               else np.array([eng.heap[i]
                              for i in range(len(expect_heap))]))
        if not np.array_equal(got, np.asarray(expect_heap)):
            bad = np.nonzero(got != np.asarray(expect_heap))[0]
            out.append(f"heap mismatch at {bad.size} addrs "
                       f"(first {bad[:8].tolist()})")
    if expect_sums is not None:
        for base, n, want in expect_sums:
            got_sum = sum(int(eng.heap[base + i]) for i in range(n))
            if got_sum != want:
                out.append(f"block sum at {base}+{n}: {got_sum} != {want}")
    return out


# ---------------------------------------------------------------------------
# MVStore handle recovery
# ---------------------------------------------------------------------------


def recover_handle(handle: Any) -> RecoveryReport:
    """Recover an ``MVStoreHandle`` after a crashed commit.

    Completes a crashed install (``_inflight`` — past the donating fused
    call the in-flight state is the ONLY copy of the store; readers are
    stranded on deleted buffers until it lands), then truncates any ring
    timestamp past the durable clock (a torn row can never satisfy a
    reader consistently, and the slot's buffer may be garbage).
    """
    import jax.numpy as jnp

    rep = RecoveryReport()
    with handle._commit_lock:
        rep.clock_before = int(handle._state.clock)
        inflight = handle._inflight
        if inflight is not None:
            handle._install(inflight)
            handle._inflight = None
            rep.completed_install = True
        state = handle._state
        durable = int(state.clock)
        if state.ring_ts:
            new_ts = {}
            changed = False
            for path, ts in state.ring_ts.items():
                host = np.asarray(ts)
                torn = host > durable
                if torn.any():
                    rep.truncated_ring_slots += int(torn.sum())
                    host = np.where(torn, np.int32(-1), host)
                    new_ts[path] = jnp.asarray(host, jnp.int32)
                    changed = True
                else:
                    new_ts[path] = ts
            if changed:
                state = state._replace(ring_ts=new_ts)
        handle._install(state)
        rep.clock_after = int(handle._state.clock)
    rep.apply_to(handle)
    FP.reset_thread()
    return rep


def check_store_invariants(handle: Any, *,
                           clock_at_least: Optional[int] = None
                           ) -> List[str]:
    """Post-recovery MVStore invariants; returns violations.

    * no in-flight (uninstalled) state;
    * clock monotone;
    * no ring timestamp past the durable clock;
    * a snapshot RESOLVES at every durable ring timestamp (the paper's
      committed-prefix promise, checked slot by slot).
    """
    out: List[str] = []
    if handle._inflight is not None:
        out.append("uninstalled in-flight commit")
    clock, live, ring, ring_ts = handle._snap
    if clock_at_least is not None and clock < clock_at_least:
        out.append(f"store clock went backwards: {clock} < {clock_at_least}")
    if ring_ts is not None:
        past = ring_ts[ring_ts > clock]
        if past.size:
            out.append(f"ring timestamps past durable clock: "
                       f"{past.tolist()}")
        from repro.core import mvstore
        for ts in sorted(int(t) for t in ring_ts if int(t) != -1):
            _view, ok = mvstore.mv_snapshot(handle._state, ts)
            if not bool(np.all(np.asarray(ok))):
                out.append(f"snapshot unreadable at durable clock {ts}")
    return out


# ---------------------------------------------------------------------------
# sharded-store recovery (cross-shard epoch publish)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class EpochRecord:
    """The cross-shard commit record: ``publish_started`` generalized
    from one transaction to one EPOCH of shard-local publishes.

    A multi-shard commit parks this in ``ShardStoreHandle._epoch_inflight``
    before bumping the epoch seqlock odd.  ``pins[s]`` is write shard
    ``s``'s clock at validation time; a shard whose clock still equals
    its pin after a crash has NOT published (each shard-local publish
    ticks its clock by exactly one), so recovery can tell redo from done
    without any per-shard journal:

      * ``publish_started`` False — the epoch never decided: roll BACK.
        No shard published (the flag flips before the first shard-local
        publish), so rollback is dropping the record and re-evening the
        seqlock.
      * ``publish_started`` True — the epoch decided: roll FORWARD.
        Replay every write shard still at its pin through the exact
        publish path (``MVStoreHandle._publish_locked`` on the parked
        per-shard context), so after recovery either ALL shards carry
        the epoch's writes or the epoch is re-driven to completion —
        never a torn cut.
    """
    epoch: int
    write_shards: tuple
    pins: dict                      # shard id -> clock pinned at validate
    ctxs: dict                      # shard id -> parked _MVCtx (write_buf)
    tid: int = -1
    publish_started: bool = False
    published: list = dataclasses.field(default_factory=list)
    # the epoch's durable twin: one WAL prepare per write shard, all
    # covered by ONE group DECIDE — so a restart replays the epoch
    # all-or-nothing (wal.recover_from_wal)
    wal_lsns: tuple = ()


def recover_shardstore(store: Any, wal: Any = None) -> RecoveryReport:
    """Recover a ``ShardStoreHandle`` after a crashed commit.

    Stop-world like every recovery here: first each member shard recovers
    exactly as a solo handle (completing crashed installs, truncating
    torn ring slots), then the epoch record applies the roll-forward /
    roll-back rule above, and finally the epoch seqlock is forced even so
    new transactions stop spinning in ``begin``.  With ``wal`` given, a
    rolled-forward epoch's durable records get their COMPLETE markers.
    """
    rep = RecoveryReport()
    rep.clock_before = int(store._epoch.load())
    for shard in store._shards:
        sub = recover_handle(shard)
        rep.truncated_ring_slots += sub.truncated_ring_slots
        rep.completed_install = rep.completed_install or sub.completed_install
    rec = store._epoch_inflight
    if rec is not None:
        if rec.publish_started:
            for s in rec.write_shards:
                shard = store._shards[s]
                if int(shard._state.clock) == rec.pins[s]:
                    # still at its pin => this shard never published:
                    # redo through the exact commit publish path
                    with shard._commit_lock:
                        shard._publish_locked(rec.ctxs[s],
                                              wal_log=False)
                    rec.published.append(s)
            rep.rolled_forward.append(rec.tid)
            if wal is not None:
                for lsn in rec.wal_lsns:
                    wal.append_complete(lsn)
        else:
            rep.rolled_back.append(rec.tid)
        for ctx in rec.ctxs.values():
            ctx.active = False
        store._epoch_inflight = None
    if store._epoch_seq.load() & 1:
        store._epoch_seq.increment()
    rep.clock_after = int(store._epoch.load())
    rep.apply_to(store)
    FP.reset_thread()
    return rep


def check_shardstore_invariants(store: Any, *,
                                clocks_at_least: Optional[Sequence[int]]
                                = None) -> List[str]:
    """Post-recovery sharded-store invariants; returns violations.

    Per-shard ``check_store_invariants`` plus the epoch level: no parked
    epoch record, epoch seqlock even (readers can pin), and every shard
    clock monotone against ``clocks_at_least``.
    """
    out: List[str] = []
    if store._epoch_inflight is not None:
        out.append("unresolved cross-shard epoch record")
    if store._epoch_seq.load() & 1:
        out.append("epoch seqlock left odd (readers starve)")
    for s, shard in enumerate(store._shards):
        floor = (None if clocks_at_least is None
                 else int(clocks_at_least[s]))
        out.extend(f"shard {s}: {v}"
                   for v in check_store_invariants(shard,
                                                   clock_at_least=floor))
    return out


# ---------------------------------------------------------------------------
# checkpoint replay (TrainSupervisor restore path)
# ---------------------------------------------------------------------------


class _RingCfg:
    def __init__(self, r: int):
        self.ring_slots = r


def replay_from_checkpoint(ckpt_dir: str, template_state):
    """Restore (step, state) from the newest manifest under ``ckpt_dir``.

    ``template_state`` supplies the pytree structure (a TrainState with
    ``.mv``/``.opt``); rings are re-seeded from the restored live values
    at the restored clock.  Raises FileNotFoundError when no checkpoint
    has landed (callers decide: cold restart).
    ``save_checkpoint``'s atomic ``os.replace`` publish means a crash at
    ``pre_manifest_publish`` leaves only a ``.tmp`` directory, which the
    restore scan skips — replay always lands on a COMPLETE manifest.
    """
    import jax

    from repro.checkpoint.snapshotter import restore_checkpoint

    tmpl = {"params": template_state.mv.live, "opt": template_state.opt}
    step, restored, _extra = restore_checkpoint(ckpt_dir, tmpl)
    mv = template_state.mv._replace(
        live=restored["params"],
        clock=jax.numpy.asarray(step, jax.numpy.int32))
    # re-seed rings from the restored live values at the restored clock
    if mv.ring:
        from repro.core import mvstore as mvs
        paths = set(mv.ring)
        mv = mv._replace(ring={}, ring_ts={})
        mv = mvs.version_blocks(mv, paths, _RingCfg(
            next(iter(template_state.mv.ring.values())).shape[0]))
    state = template_state._replace(mv=mv, opt=restored["opt"])
    return step, state
