"""Named fault-injection points for the commit pipelines.

Design contract with the hot paths:

* Call sites guard with ``if FP.ACTIVE is not None: FP.fire(point, tid)``
  — when no schedule is installed the cost is one module-attribute load
  and a ``None`` check.  This module is stdlib-only so the engine can
  import it without pulling in numpy/jax.
* A fired fault either raises ``FaultError`` (an ordinary error the
  retry machinery may handle), or simulates a crash.  Simulated crashes
  derive from ``BaseException`` and carry ``simulated_crash = True``;
  cleanup sites that model *transaction semantics* (abort, lock
  release, undo restore) must skip their work when they see that flag,
  because a real crash would never have run them.  Cleanup that models
  *hardware* (releasing an emulation mutex such as a stripe lock — the
  stand-in for an instantaneous CAS) still runs on unwind.
* ``fire`` sets a thread-local ``dying`` flag before raising a crash so
  nested hooks on the unwind path never double-fire, and so cleanup
  code can ask ``FP.dying()`` directly.

The eight points::

    pre_claim           before write locks are claimed
    post_claim          after all write locks are held
    pre_clock_tick      before the commit timestamp is taken
    pre_scatter         before heap publication starts
    mid_scatter         INSIDE the publish sweep — some lanes already
                        scattered, the rest not (the commit_fused
                        partial-lane completion fault; recovery must
                        redo the whole record idempotently)
    post_scatter        after heap publication completes
    pre_release         before write locks are released
    pre_manifest_publish before the checkpoint manifest rename

Actions: ``raise`` (recoverable ``FaultError``), ``kill`` (the owning
thread dies), ``crash`` (the simulated process drops; in-memory state
survives for the in-process recovery drills), and ``die`` — the REAL
thing: ``SIGKILL`` to our own pid, discarding ALL in-memory state.
``die`` is for subprocess drills only (the parent restarts a fresh
process and recovers from the durable WAL); firing it inside a test
runner would take the runner down with it.
"""
from __future__ import annotations

import os
import random
import signal
import threading
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

FAULT_POINTS: Tuple[str, ...] = (
    "pre_claim",
    "post_claim",
    "pre_clock_tick",
    "pre_scatter",
    "mid_scatter",
    "post_scatter",
    "pre_release",
    "pre_manifest_publish",
)

ACTIONS: Tuple[str, ...] = ("raise", "kill", "crash", "die")


class FaultError(RuntimeError):
    """An injected recoverable error (the txn machinery may retry)."""

    def __init__(self, point: str, tid: int = -1):
        super().__init__(f"injected fault at {point} (tid={tid})")
        self.point = point
        self.tid = tid


class SimulatedCrash(BaseException):
    """Base for injected crashes.

    Derives from BaseException so ``except Exception`` handlers in the
    code under test don't swallow it; ``simulated_crash`` is the flag
    transaction-semantic cleanup must check before undoing anything.
    """

    simulated_crash = True

    def __init__(self, point: str, tid: int = -1):
        super().__init__(f"simulated crash at {point} (tid={tid})")
        self.point = point
        self.tid = tid


class ThreadKilled(SimulatedCrash):
    """The owning thread died mid-commit; the process lives on."""


class ProcessCrashed(SimulatedCrash):
    """The whole simulated process dropped; recovery restarts it."""


class SimulatedProcessDeath(ProcessCrashed):
    """The OS process image is GONE — every in-memory structure (heap,
    lock table, descriptors, parked epoch records) is lost.  The ``die``
    action delivers a real ``SIGKILL``; this exception only surfaces if
    the signal could not be delivered (never, on POSIX)."""


def is_simulated_crash(exc: BaseException) -> bool:
    return getattr(exc, "simulated_crash", False)


@dataclass(frozen=True)
class Fault:
    """One explicit injection: fire ``action`` on the ``nth`` arrival
    at ``point`` (1-based, counted per point), optionally only for one
    thread id."""

    point: str
    nth: int = 1
    action: str = "kill"
    tid: Optional[int] = None

    def __post_init__(self):
        if self.point not in FAULT_POINTS:
            raise ValueError(f"unknown fault point {self.point!r}")
        if self.action not in ACTIONS:
            raise ValueError(f"unknown fault action {self.action!r}")
        if self.nth < 1:
            raise ValueError("nth is 1-based")


class FaultSchedule:
    """Deterministic schedule of injected faults.

    Two modes, composable:

    * explicit ``faults`` — a list of :class:`Fault` records, each
      matched against a per-(point, tid-filter) arrival counter;
    * periodic ``kill_every`` — roughly every ``kill_every``-th arrival
      at one of ``points`` fires ``action``, with the exact gap drawn
      from ``random.Random(seed)`` so runs are replayable but not
      phase-locked to the workload.

    ``max_fires`` caps total injections (None = unlimited).  The
    ``fired`` journal records ``(point, tid, action, arrival_index)``
    for every injection, in order.
    """

    def __init__(
        self,
        faults: Sequence[Fault] = (),
        *,
        seed: int = 0,
        kill_every: int = 0,
        points: Sequence[str] = ("pre_release",),
        action: str = "kill",
        max_fires: Optional[int] = None,
    ):
        for p in points:
            if p not in FAULT_POINTS:
                raise ValueError(f"unknown fault point {p!r}")
        if action not in ACTIONS:
            raise ValueError(f"unknown fault action {action!r}")
        self.faults: Tuple[Fault, ...] = tuple(faults)
        self.seed = seed
        self.kill_every = int(kill_every)
        self.periodic_points = frozenset(points)
        self.periodic_action = action
        self.max_fires = max_fires
        self.fired: List[Tuple[str, int, str, int]] = []
        self._lock = threading.Lock()
        self._arrivals: Dict[str, int] = {p: 0 for p in FAULT_POINTS}
        self._total_arrivals = 0
        self._pending = list(self.faults)
        self._rng = random.Random(seed)
        self._next_periodic = self._draw_gap() if self.kill_every else -1
        self.process_dead = False

    def _draw_gap(self) -> int:
        # jitter +-25% around kill_every, never below 1
        lo = max(1, (3 * self.kill_every) // 4)
        hi = max(lo, (5 * self.kill_every) // 4)
        return self._total_arrivals + self._rng.randint(lo, hi)

    def arrive(self, point: str, tid: int) -> Optional[str]:
        """Record an arrival; return the action to take, or None."""
        with self._lock:
            if self.max_fires is not None and len(self.fired) >= self.max_fires:
                return None
            self._arrivals[point] += 1
            n = self._arrivals[point]
            for i, f in enumerate(self._pending):
                if f.point != point or f.nth != n:
                    continue
                if f.tid is not None and f.tid != tid:
                    continue
                del self._pending[i]
                self.fired.append((point, tid, f.action, n))
                return f.action
            if self.kill_every and point in self.periodic_points:
                self._total_arrivals += 1
                if self._total_arrivals >= self._next_periodic:
                    self._next_periodic = self._draw_gap()
                    self.fired.append((point, tid, self.periodic_action, n))
                    return self.periodic_action
            return None

    def arrivals(self, point: Optional[str] = None) -> int:
        with self._lock:
            if point is None:
                return sum(self._arrivals.values())
            return self._arrivals[point]


# --- global install point --------------------------------------------------

ACTIVE: Optional[FaultSchedule] = None

_tls = threading.local()


def install(schedule: FaultSchedule) -> FaultSchedule:
    global ACTIVE
    ACTIVE = schedule
    return schedule


def uninstall() -> None:
    global ACTIVE
    ACTIVE = None


class installed:
    """Context manager: install a schedule, always uninstall on exit."""

    def __init__(self, schedule: FaultSchedule):
        self.schedule = schedule

    def __enter__(self) -> FaultSchedule:
        return install(self.schedule)

    def __exit__(self, *exc) -> None:
        uninstall()
        return None


def dying() -> bool:
    """True while the current thread is unwinding from a simulated crash."""
    return getattr(_tls, "dying", False)


def reset_thread() -> None:
    """Clear the dying flag — call when a 'dead' worker is resurrected."""
    _tls.dying = False


def fire(point: str, tid: int = -1) -> None:
    """Arrival at a fault point.  No-op unless a schedule is installed.

    Raises FaultError / ThreadKilled / ProcessCrashed per the schedule.
    """
    sched = ACTIVE
    if sched is None or getattr(_tls, "dying", False):
        return
    action = sched.arrive(point, tid)
    if action is None:
        return
    if action == "raise":
        raise FaultError(point, tid)
    _tls.dying = True
    if action == "kill":
        raise ThreadKilled(point, tid)
    sched.process_dead = True
    if action == "die":
        # the real thing: no unwind, no cleanup, no exception — the
        # kernel reaps us mid-instruction (subprocess drills only)
        os.kill(os.getpid(), signal.SIGKILL)
        raise SimulatedProcessDeath(point, tid)  # pragma: no cover
    raise ProcessCrashed(point, tid)
