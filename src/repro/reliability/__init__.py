"""repro.reliability — deterministic fault injection + crash recovery.

The paper's consistency claim ("a versioned snapshot is always a
committed prefix") is exactly the property a crash-recovery path needs,
so this package makes failure a first-class, replayable scenario:

  * ``faultpoints`` — named injection points threaded through the commit
    pipelines (solo, group, MVStore fused publish) and the checkpointer.
    Install a seeded ``FaultSchedule`` and a chosen arrival raises,
    kills the owning thread mid-commit, or drops a simulated process.
    With nothing installed every hook is one module-attribute load — the
    hot paths pay nothing.
  * ``recovery`` — scans the heap / lock table / MV ring after a
    simulated crash: releases orphaned locks held by dead owners, rolls
    encounter-time writes back from undo logs, rolls decided buffered
    commits FORWARD from their write maps (the ``publish_started``
    commit record), truncates torn ring rows past the last durable
    clock, repairs torn PackedVLT mirror rows, and replays training
    state from the latest checkpoint manifest.
  * ``workload`` — the ``reliability`` eval: rwmix under a seeded kill
    schedule with live recovery + worker rejoin (``runtime/elastic``),
    violation-gated like every other eval headline.

Import ``faultpoints`` directly from hot paths; the heavier modules load
lazily so the engine never pays for jax.
"""
from repro.reliability.faultpoints import (  # noqa: F401
    FAULT_POINTS,
    Fault,
    FaultError,
    FaultSchedule,
    ProcessCrashed,
    SimulatedCrash,
    SimulatedProcessDeath,
    ThreadKilled,
)

__all__ = [
    "FAULT_POINTS", "Fault", "FaultError", "FaultSchedule",
    "ProcessCrashed", "SimulatedCrash", "SimulatedProcessDeath",
    "ThreadKilled",
    "recover_engine", "recover_handle", "RecoveryReport",
    "WriteAheadLog", "attach_wal", "recover_from_wal",
]


def __getattr__(name):
    # recovery/wal pull in numpy/engine internals; keep the package
    # import featherweight for the faultpoints hooks in core modules
    if name in ("recover_engine", "recover_handle", "RecoveryReport",
                "check_engine_invariants", "check_store_invariants",
                "replay_from_checkpoint"):
        from repro.reliability import recovery
        return getattr(recovery, name)
    if name in ("WriteAheadLog", "attach_wal", "recover_from_wal",
                "WalRecord", "scan_dir"):
        from repro.reliability import wal
        return getattr(wal, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
