"""Multi-backend bake-off: ONE workload, every substrate, one API.

The same transfer/audit workload (quickstart's) runs via `make_tm` on all
five word-level TMs and the Layer-B MVStore; because `stats()` is one
schema everywhere, the comparison table needs zero per-backend glue.
A validation microbenchmark then times the engine's commit-time read-set
revalidation both ways — the word-at-a-time scalar loop vs the bulk
vectorized path (`engine.validation` / `kernels/validate.py`) — across
read-set sizes; the read_bulk microbench does the same for flat long
reads, the commitbulk microbench for the COMMIT pipeline (one
`try_lock_bulk` CAS sweep + one heap scatter + one `unlock_bulk` vs
the word-at-a-time loop, asserted >=3x at 1k-word write sets), and the
structrq microbench for pointer-chasing ones (the frontier-at-a-time
`HashMap.size_query` vs the scalar chain walk, asserted >=3x at 4k
keys).

    PYTHONPATH=src python examples/bakeoff.py [--seconds 1.0] [--quick]
"""
import argparse
import threading
import time

from repro.api import MaxRetriesExceeded, atomic, backend_names, make_tm, run
from repro.configs.paper_stm import MultiverseParams

N_ACCOUNTS = 100
INITIAL = 100


def bake(backend: str, seconds: float):
    tm = make_tm(backend, n_threads=3,
                 params=MultiverseParams(k1=4, lock_table_bits=10))
    base = tm.alloc(N_ACCOUNTS, INITIAL)
    stop = threading.Event()
    done = [0, 0]

    @atomic(tm)
    def transfer(tx, src, dst, amt):
        a = tx.read(base + src)
        b = tx.read(base + dst)
        tx.write(base + src, a - amt)
        tx.write(base + dst, b + amt)

    def worker(tid):
        i = 0
        while not stop.is_set():
            src, dst = i % N_ACCOUNTS, (i * 13 + 7) % N_ACCOUNTS
            if src != dst:
                transfer(src, dst, 5, tid=tid)
                done[tid] += 1
            i += 1

    ths = [threading.Thread(target=worker, args=(t,)) for t in (0, 1)]
    [t.start() for t in ths]
    audits = failed = 0
    t0 = time.time()
    while time.time() - t0 < seconds:
        try:
            total = run(tm, lambda tx: sum(tx.read(base + i)
                                           for i in range(N_ACCOUNTS)),
                        tid=2, max_retries=500)
            assert total == N_ACCOUNTS * INITIAL, "torn read!"
            audits += 1
        except MaxRetriesExceeded:
            failed += 1                   # the starvation the paper fixes
    stop.set()
    [t.join() for t in ths]
    st = tm.stats()
    tm.stop()
    return {"backend": backend, "transfers": sum(done), "audits": audits,
            "failed_audits": failed, **{k: st[k] for k in
            ("aborts", "versioned_commits", "mode")}}


def validation_microbench(sizes=(256, 1024, 4096, 16384), repeats=5):
    """Commit-time revalidation: scalar loop vs bulk vectorized path.

    Builds a real engine lock table, populates a read set of each size
    through actual transactional reads, and times
    `validation.revalidate_scalar` against `validation.revalidate_bulk`
    on identical inputs.  Returns rows; asserts the two agree.
    """
    from repro.core.engine import validation as V

    tm = make_tm("tl2", n_threads=1,
                 params=MultiverseParams(lock_table_bits=16))
    base = tm.alloc(max(sizes), 1)
    raw = tm.raw
    rows = []
    for n in sizes:
        tx = raw.begin(0)
        for i in range(n):
            tx.read(base + i)
        d = tx._ctx

        def timeit(fn):
            best = float("inf")
            for _ in range(repeats):
                t0 = time.perf_counter()
                ok = fn()
                best = min(best, time.perf_counter() - t0)
            return ok, best

        ok_s, t_scalar = timeit(lambda: V.revalidate_scalar(
            raw.locks, d.read_set, d.r_clock, d.tid, V.V_LE))
        ok_b, t_bulk = timeit(lambda: V.revalidate_bulk(
            raw.locks, d.read_set, d.r_clock, d.tid, V.V_LE))
        assert ok_s == ok_b, "scalar and bulk validators disagree"
        raw._abort(d)
        rows.append({"reads": n, "scalar_us": t_scalar * 1e6,
                     "bulk_us": t_bulk * 1e6,
                     "speedup": t_scalar / max(t_bulk, 1e-12)})
    tm.stop()
    return rows


def readbulk_microbench(sizes=(1024, 4096, 16384), repeats=5,
                        backend="multiverse"):
    """Long-running read: scalar `tx.read` loop vs one `tx.read_bulk`.

    A quiescent TM on the int64 array heap, one read-only transaction per
    measurement — so the comparison isolates the read path itself: N
    Python round-trips (lock read + validate each) against one heap
    gather bracketed by two lock-word gathers.  Asserts the two agree.
    """
    import numpy as np

    tm = make_tm(backend, n_threads=1,
                 params=MultiverseParams(lock_table_bits=16),
                 array_heap=True)
    base = tm.alloc(max(sizes), 1)
    rows = []
    for n in sizes:
        # run() not txn(): the deferred clock aborts the very first
        # access after construction once (see API.md), and run retries
        def scalar():
            return run(tm, lambda tx: sum(tx.read(base + i)
                                          for i in range(n)), tid=0)

        def bulk():
            return run(tm, lambda tx: int(np.sum(np.asarray(
                tx.read_bulk(range(base, base + n))))), tid=0)

        def timeit(fn):
            best, val = float("inf"), None
            for _ in range(repeats):
                t0 = time.perf_counter()
                val = fn()
                best = min(best, time.perf_counter() - t0)
            return val, best

        v_s, t_scalar = timeit(scalar)
        v_b, t_bulk = timeit(bulk)
        assert v_s == v_b == n, "scalar and bulk reads disagree"
        rows.append({"reads": n, "scalar_us": t_scalar * 1e6,
                     "bulk_us": t_bulk * 1e6,
                     "speedup": t_scalar / max(t_bulk, 1e-12)})
    tm.stop()
    return rows


def commitbulk_microbench(sizes=(256, 1024, 4096), repeats=5,
                          backend="tl2"):
    """Commit pipeline: scalar loop vs batched acquire/write-back/release.

    A quiescent TL2 on the int64 array heap; each measurement buffers an
    n-word write set through real ``tx.write`` calls, then times the
    three commit-pipeline steps on the SAME descriptor both ways: the
    word-at-a-time scalar loop (``bulk_min`` forced past the write set)
    vs the batched pipeline (one ``try_lock_bulk`` CAS sweep + one heap
    scatter + one ``unlock_bulk``).  Asserts both leave identical heap
    state; returns timing rows.
    """
    import numpy as np

    from repro.core.engine import commit as Cm

    tm = make_tm(backend, n_threads=1,
                 params=MultiverseParams(lock_table_bits=16),
                 array_heap=True)
    base = tm.alloc(max(sizes), 0)
    raw = tm.raw
    rows = []
    inf = 1 << 60
    for n in sizes:
        def pipeline(bulk_min):
            tx = raw.begin(0)
            for i in range(n):
                tx.write(base + i, i + 1)
            d = tx._ctx
            t0 = time.perf_counter()
            locked = Cm.acquire_write_locks(raw, d, bulk_min=bulk_min)
            wv = raw.clock.increment()
            Cm.write_back(raw, d, bulk_min=bulk_min)
            Cm.release_locks(raw, locked, wv, bulk_min=bulk_min)
            dt = time.perf_counter() - t0
            snap = np.asarray(raw.heap.gather(
                np.arange(base, base + n, dtype=np.int64)))
            d.reset()
            d.active = False
            return dt, snap

        def timeit(bulk_min):
            best, snap = float("inf"), None
            for _ in range(repeats):
                dt, snap = pipeline(bulk_min)
                best = min(best, dt)
            return best, snap

        t_scalar, snap_s = timeit(inf)
        t_bulk, snap_b = timeit(0)
        assert (snap_s == snap_b).all(), \
            "scalar and bulk commit pipelines disagree"
        rows.append({"writes": n, "scalar_us": t_scalar * 1e6,
                     "bulk_us": t_bulk * 1e6,
                     "speedup": t_scalar / max(t_bulk, 1e-12)})
    tm.stop()
    return rows


def structrq_microbench(n_keys=4096, n_buckets=1 << 10, repeats=3):
    """Struct long read: frontier-at-a-time walk vs the scalar traversal.

    A quiescent hashmap with ``n_keys`` keys over ``n_buckets`` chained
    buckets (load factor 4, so chains are real).  The frontier walk is
    the shipped ``HashMap.size_query`` (bucket heads in one ``read_bulk``
    batch, then every chain advancing in lockstep via
    ``engine.traverse.chase_bulk``); the scalar reference hops each
    chain word-at-a-time through ``tx.read`` — the pre-traversal-layer
    implementation.  Asserts the two agree; returns timing rows.
    """
    from repro.structs import HashMap

    tm = make_tm("multiverse", n_threads=1,
                 params=MultiverseParams(lock_table_bits=16),
                 array_heap=True)
    h = HashMap(tm, n_buckets=n_buckets)
    for k in range(n_keys):
        run(tm, lambda tx, k=k: h.insert(tx, k, k), tid=0)

    def scalar_sq(tx):
        total = 0
        heads = tx.read_bulk(range(h.table, h.table + h.n_buckets))
        for node in heads:
            node = int(node)
            while node:
                total += 1
                node = int(tx.read(node + 2))
        return total

    def timeit(fn):
        best, val = float("inf"), None
        for _ in range(repeats):
            t0 = time.perf_counter()
            val = run(tm, fn, tid=0)
            best = min(best, time.perf_counter() - t0)
        return val, best

    v_f, t_frontier = timeit(h.size_query)
    v_s, t_scalar = timeit(scalar_sq)
    assert v_f == v_s == n_keys, (v_f, v_s)
    tm.stop()
    return [{"keys": n_keys, "scalar_us": t_scalar * 1e6,
             "frontier_us": t_frontier * 1e6,
             "speedup": t_scalar / max(t_frontier, 1e-12)}]


def groupcommit_microbench(n_txns=(2, 4, 8), words=256, repeats=9,
                           backend="tl2"):
    """Group commit: N solo commit pipelines vs ONE fused group window.

    N ready transactions each buffer a disjoint ``words``-word block
    (consecutive addresses — collision-free under the Fibonacci lock
    hash at ``lock_table_bits=16``, so the batcher forms one group).
    Each measurement builds the same N write sets twice on the SAME
    heap and times only the commit phase: the solo loop (N full batched
    pipelines, N clock ticks) vs ``CommitBatcher.commit_all`` (one
    striped verdict+claim window, ONE clock tick, one scatter, one
    release sweep).  Asserts every commit succeeded, that the batcher
    really grouped, and that both paths leave the heap exactly at the
    payload both were asked to write.
    """
    import numpy as np

    from repro.core.engine.groupcommit import CommitBatcher

    tm = make_tm(backend, n_threads=max(n_txns) + 1,
                 params=MultiverseParams(lock_table_bits=16),
                 array_heap=True)
    raw = tm.raw
    base = tm.alloc(max(n_txns) * words, 0)
    rows = []
    payload = [0]

    def build(n):
        payload[0] += 1
        txs = []
        for t in range(n):
            tx = raw.begin(t)
            lo = base + t * words
            for i in range(words):
                tx.write(lo + i, payload[0] * 1000000 + t * words + i)
            txs.append(tx)
        return txs

    def check(n):
        got = np.asarray(raw.heap.gather(
            np.arange(base, base + n * words, dtype=np.int64)))
        want = payload[0] * 1000000 + np.arange(n * words)
        assert (got == want).all(), "commit left the heap wrong"

    for n in n_txns:
        def solo():
            txs = build(n)
            t0 = time.perf_counter()
            for tx in txs:
                raw._try_commit(tx._ctx)
            dt = time.perf_counter() - t0
            check(n)
            return dt

        def grouped():
            txs = build(n)
            b = CommitBatcher(raw)
            for tx in txs:
                b.add(tx)
            t0 = time.perf_counter()
            ok = b.commit_all()
            dt = time.perf_counter() - t0
            assert all(ok), "group commit aborted a disjoint member"
            assert b.stats["groups"] == 1 and b.stats["grouped"] == n, \
                f"disjoint blocks did not form one group: {b.stats}"
            check(n)
            return dt

        t_solo = min(solo() for _ in range(repeats))
        t_grp = min(grouped() for _ in range(repeats))
        rows.append({"txns": n, "words": words, "solo_us": t_solo * 1e6,
                     "grouped_us": t_grp * 1e6,
                     "speedup": t_solo / max(t_grp, 1e-12)})
    tm.stop()
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--seconds", type=float, default=1.0)
    ap.add_argument("--backends", nargs="*", default=None)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: short runs, fewer backends")
    ap.add_argument("--skip-validate-bench", action="store_true")
    args = ap.parse_args()
    if args.quick:
        args.seconds = min(args.seconds, 0.3)
    if args.backends is None:
        args.backends = (["multiverse", "tl2", "norec"] if args.quick
                         else list(backend_names()))
    print(f"{'backend':10s} {'transfers':>9s} {'audits':>6s} "
          f"{'failed':>6s} {'aborts':>7s} {'versioned':>9s} mode")
    for b in args.backends:
        r = bake(b, args.seconds)
        print(f"{r['backend']:10s} {r['transfers']:9d} {r['audits']:6d} "
              f"{r['failed_audits']:6d} {r['aborts']:7d} "
              f"{r['versioned_commits']:9d} {r['mode']}")
    if args.skip_validate_bench:
        return
    print("\nread-set revalidation: scalar loop vs bulk vectorized path")
    print(f"{'reads':>7s} {'scalar_us':>10s} {'bulk_us':>9s} "
          f"{'speedup':>8s}")
    sizes = (1024, 4096) if args.quick else (256, 1024, 4096, 16384)
    beats_at_1k = None
    for row in validation_microbench(sizes=sizes):
        print(f"{row['reads']:7d} {row['scalar_us']:10.1f} "
              f"{row['bulk_us']:9.1f} {row['speedup']:7.1f}x")
        if row["reads"] >= 1024 and beats_at_1k is None:
            beats_at_1k = row["speedup"] > 1.0
    assert beats_at_1k, "bulk validation did not beat the scalar loop"

    print("\nlong-running read: scalar tx.read loop vs one tx.read_bulk")
    print(f"{'reads':>7s} {'scalar_us':>10s} {'bulk_us':>9s} "
          f"{'speedup':>8s}")
    sizes = (1024, 4096) if args.quick else (1024, 4096, 16384)
    beats_at_4k = None
    for row in readbulk_microbench(sizes=sizes):
        print(f"{row['reads']:7d} {row['scalar_us']:10.1f} "
              f"{row['bulk_us']:9.1f} {row['speedup']:7.1f}x")
        if row["reads"] >= 4096 and beats_at_4k is None:
            beats_at_4k = row["speedup"] >= 4.0
    assert beats_at_4k, "read_bulk did not beat the scalar loop 4x at 4k"

    print("\ncommit pipeline: scalar loop vs batched "
          "acquire/write-back/release")
    print(f"{'writes':>7s} {'scalar_us':>10s} {'bulk_us':>9s} "
          f"{'speedup':>8s}")
    sizes = (1024,) if args.quick else (256, 1024, 4096)
    beats_at_1k = None
    for row in commitbulk_microbench(sizes=sizes):
        print(f"{row['writes']:7d} {row['scalar_us']:10.1f} "
              f"{row['bulk_us']:9.1f} {row['speedup']:7.1f}x")
        if row["writes"] >= 1024 and beats_at_1k is None:
            beats_at_1k = row["speedup"] >= 3.0
    assert beats_at_1k, \
        "bulk commit did not beat the scalar pipeline 3x at 1k writes"

    print("\ngroup commit: N solo commit pipelines vs one fused group")
    print(f"{'txns':>5s} {'words':>6s} {'solo_us':>9s} {'grouped_us':>10s} "
          f"{'speedup':>8s}")
    n_txns = (8,) if args.quick else (2, 4, 8)
    beats_at_8 = None
    for row in groupcommit_microbench(n_txns=n_txns):
        print(f"{row['txns']:5d} {row['words']:6d} {row['solo_us']:9.1f} "
              f"{row['grouped_us']:10.1f} {row['speedup']:7.1f}x")
        if row["txns"] >= 8 and beats_at_8 is None:
            beats_at_8 = row["speedup"] >= 3.0
    assert beats_at_8, \
        "group commit did not beat the solo loop 3x at 8 txns"

    print("\nstruct long read: scalar chain walk vs frontier-at-a-time")
    print(f"{'keys':>7s} {'scalar_us':>10s} {'frontier_us':>11s} "
          f"{'speedup':>8s}")
    for row in structrq_microbench(n_keys=4096):
        print(f"{row['keys']:7d} {row['scalar_us']:10.1f} "
              f"{row['frontier_us']:11.1f} {row['speedup']:7.1f}x")
        assert row["speedup"] >= 3.0, \
            "frontier walk did not beat the scalar traversal 3x at 4k keys"


if __name__ == "__main__":
    main()
