"""Multi-backend bake-off: ONE workload, every substrate, one API.

The same transfer/audit workload (quickstart's) runs via `make_tm` on all
five word-level TMs and the Layer-B MVStore; because `stats()` is one
schema everywhere, the comparison table needs zero per-backend glue.

    PYTHONPATH=src python examples/bakeoff.py [--seconds 1.0]
"""
import argparse
import threading
import time

from repro.api import MaxRetriesExceeded, atomic, backend_names, make_tm, run
from repro.configs.paper_stm import MultiverseParams

N_ACCOUNTS = 100
INITIAL = 100


def bake(backend: str, seconds: float):
    tm = make_tm(backend, n_threads=3,
                 params=MultiverseParams(k1=4, lock_table_bits=10))
    base = tm.alloc(N_ACCOUNTS, INITIAL)
    stop = threading.Event()
    done = [0, 0]

    @atomic(tm)
    def transfer(tx, src, dst, amt):
        a = tx.read(base + src)
        b = tx.read(base + dst)
        tx.write(base + src, a - amt)
        tx.write(base + dst, b + amt)

    def worker(tid):
        i = 0
        while not stop.is_set():
            src, dst = i % N_ACCOUNTS, (i * 13 + 7) % N_ACCOUNTS
            if src != dst:
                transfer(src, dst, 5, tid=tid)
                done[tid] += 1
            i += 1

    ths = [threading.Thread(target=worker, args=(t,)) for t in (0, 1)]
    [t.start() for t in ths]
    audits = failed = 0
    t0 = time.time()
    while time.time() - t0 < seconds:
        try:
            total = run(tm, lambda tx: sum(tx.read(base + i)
                                           for i in range(N_ACCOUNTS)),
                        tid=2, max_retries=500)
            assert total == N_ACCOUNTS * INITIAL, "torn read!"
            audits += 1
        except MaxRetriesExceeded:
            failed += 1                   # the starvation the paper fixes
    stop.set()
    [t.join() for t in ths]
    st = tm.stats()
    tm.stop()
    return {"backend": backend, "transfers": sum(done), "audits": audits,
            "failed_audits": failed, **{k: st[k] for k in
            ("aborts", "versioned_commits", "mode")}}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--seconds", type=float, default=1.0)
    ap.add_argument("--backends", nargs="*", default=list(backend_names()))
    args = ap.parse_args()
    print(f"{'backend':10s} {'transfers':>9s} {'audits':>6s} "
          f"{'failed':>6s} {'aborts':>7s} {'versioned':>9s} mode")
    for b in args.backends:
        r = bake(b, args.seconds)
        print(f"{r['backend']:10s} {r['transfers']:9d} {r['audits']:6d} "
              f"{r['failed_audits']:6d} {r['aborts']:7d} "
              f"{r['versioned_commits']:9d} {r['mode']}")


if __name__ == "__main__":
    main()
