"""Quickstart: the unified transactional API in 60 lines.

Two threads move money between accounts while a third takes consistent
snapshots of all balances — the paper's long-running read.  The SAME code
runs on any backend: pass `--backend tl2` (or dctl/norec/tinystm) to watch
an unversioned TM handle the audit, or `--backend mvstore` to run it on
the Layer-B parameter store.  Run:

    PYTHONPATH=src python examples/quickstart.py [--backend multiverse]
"""
import argparse
import threading
import time

from repro.api import atomic, make_tm, run
from repro.configs.paper_stm import MultiverseParams

N_ACCOUNTS = 200
INITIAL = 100


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="multiverse")
    args = ap.parse_args()

    tm = make_tm(args.backend, n_threads=3,
                 params=MultiverseParams(k1=4, lock_table_bits=10))
    base = tm.alloc(N_ACCOUNTS, INITIAL)
    stop = threading.Event()

    @atomic(tm)
    def transfer(tx, src, dst, amt):
        a = tx.read(base + src)
        b = tx.read(base + dst)
        tx.write(base + src, a - amt)
        tx.write(base + dst, b + amt)

    def transfer_worker(tid):
        i = 0
        while not stop.is_set():
            src, dst = i % N_ACCOUNTS, (i * 13 + 7) % N_ACCOUNTS
            if src != dst:
                transfer(src, dst, 5, tid=tid)
            i += 1

    workers = [threading.Thread(target=transfer_worker, args=(t,))
               for t in (0, 1)]
    [w.start() for w in workers]

    # long-running reads: sum every balance, atomically, while transfers
    # fly — alternating the word-at-a-time spelling with the batched one
    # (read_bulk snapshots the whole range in one gather)
    def audit(tx):
        return sum(tx.read(base + i) for i in range(N_ACCOUNTS))

    def audit_bulk(tx):
        return int(sum(tx.read_bulk(range(base, base + N_ACCOUNTS))))

    for trial in range(5):
        total = run(tm, audit_bulk if trial % 2 else audit, tid=2)
        assert total == N_ACCOUNTS * INITIAL, "torn read!"
        print(f"audit {trial}: total={total} (consistent) "
              f"mode={tm.stats()['mode']}")
        time.sleep(0.1)

    stop.set()
    [w.join() for w in workers]
    s = tm.stats()
    print(f"backend={s['backend']} commits={s['commits']} "
          f"aborts={s['aborts']} versioned_commits={s['versioned_commits']} "
          f"mode_transitions={s['mode_transitions']}")
    tm.stop()


if __name__ == "__main__":
    main()
