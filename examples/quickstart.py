"""Quickstart: the Multiverse STM in 60 lines.

Two threads move money between accounts while a third takes consistent
snapshots of all balances — the paper's long-running read.  Run:

    PYTHONPATH=src python examples/quickstart.py
"""
import threading
import time

from repro.configs.paper_stm import MultiverseParams
from repro.core.stm import Multiverse, run

N_ACCOUNTS = 200
INITIAL = 100


def main():
    tm = Multiverse(n_threads=3,
                    params=MultiverseParams(k1=4, lock_table_bits=10))
    base = tm.alloc(N_ACCOUNTS, INITIAL)
    stop = threading.Event()

    def transfer_worker(tid):
        i = 0
        while not stop.is_set():
            src, dst, amt = i % N_ACCOUNTS, (i * 13 + 7) % N_ACCOUNTS, 5
            if src != dst:
                def txn(tx):
                    a = tx.read(base + src)
                    b = tx.read(base + dst)
                    tx.write(base + src, a - amt)
                    tx.write(base + dst, b + amt)
                run(tm, txn, tid=tid)
            i += 1

    workers = [threading.Thread(target=transfer_worker, args=(t,))
               for t in (0, 1)]
    [w.start() for w in workers]

    # long-running reads: sum every balance, atomically, while transfers fly
    for trial in range(5):
        def audit(tx):
            return sum(tx.read(base + i) for i in range(N_ACCOUNTS))
        total = run(tm, audit, tid=2)
        assert total == N_ACCOUNTS * INITIAL, "torn read!"
        print(f"audit {trial}: total={total} (consistent) "
              f"mode={tm.stats()['mode']}")
        time.sleep(0.1)

    stop.set()
    [w.join() for w in workers]
    s = tm.stats()
    print(f"commits={s['commits']} aborts={s['aborts']} "
          f"versioned_commits={s['versioned_commits']} "
          f"mode_transitions={s['mode_transitions']}")
    tm.stop()


if __name__ == "__main__":
    main()
