"""The paper's scenario at pod scale: SERVE from a model that is TRAINING.

A trainer commits optimizer steps into the MVStore while a server thread
answers generation requests from consistent parameter snapshots.  In Mode
Q the server's reads abort whenever training commits first (watch the
abort counter); once the store versions parameters (Mode U ring), every
request is served from the newest committed snapshot without ever pausing
training — the long-running-read guarantee of Multiverse.

The server rides the ``repro.serve`` continuous-batching scheduler: all
requests are submitted up front, the pump loop keeps the slot pool full
(a freed slot is re-prefilled immediately while the other slots keep
decoding), and each request records the snapshot clocks it was actually
served at.  The final line prints the serving counters in the normalized
TM stats schema — snapshot-read retries show up as ``aborts``.

    PYTHONPATH=src python examples/serve_snapshots.py --steps 30

(For the word-granularity spelling of the same begin/commit vocabulary —
and a store-level handle that speaks it too — see `repro.api` and
examples/quickstart.py; `make_tm("mvstore", ...)` runs this pattern as
literal read-only transactions.)
"""
import argparse
import threading
import time

import numpy as np

from repro.configs import MVStoreConfig, ShapeConfig, smoke_config
from repro.core import mvcontroller
from repro.launch.serve import Server
from repro.launch.train import Trainer
from repro.serve import Outcome


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--requests", type=int, default=6)
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    shape = ShapeConfig("t", 32, 2, "train")
    controller = mvcontroller.MVController(
        mvcfg=MVStoreConfig(ring_slots=2, mode="U"))
    trainer = Trainer(cfg, shape, mvcfg=MVStoreConfig(mode="U"),
                      controller=controller)
    server = Server(cfg, batch=2, prompt_len=16, max_len=32,
                    mvcfg=MVStoreConfig(mode="U"), controller=controller,
                    mv_state=trainer.state.mv)

    rng = np.random.default_rng(0)
    reqs = [server.submit(rng.integers(0, cfg.vocab_size, size=(16,),
                                       dtype=np.int32), max_new=8)
            for _ in range(args.requests)]
    stop = threading.Event()

    def serve_loop():
        reported = set()
        while not stop.is_set() and any(
                r.outcome is Outcome.PENDING for r in reqs):
            server.mv_state = trainer.state.mv       # follow the trainer
            if not server.pump():
                time.sleep(1e-4)
            for r in reqs:
                if r.outcome is Outcome.COMPLETED and r.rid not in reported:
                    reported.add(r.rid)
                    print(f"  [server] request {r.rid} generated "
                          f"{len(r.tokens)} tokens at clocks "
                          f"{r.served_clocks[0]}..{r.served_clocks[-1]} "
                          f"(aborts so far: {server.aborts})", flush=True)

    th = threading.Thread(target=serve_loop)
    th.start()
    state = trainer.state
    for s in range(args.steps):
        state, metrics = trainer.train_step(state, trainer.batch_at(s))
        trainer.state = state
        if (s + 1) % 10 == 0:
            print(f"[trainer] step {s+1} loss={float(metrics['loss']):.4f}"
                  f" clock={int(state.mv.clock)} "
                  f"rings={len(state.mv.ring)}", flush=True)
    th.join(timeout=120.0)
    stop.set()
    th.join()
    controller.stop()
    done = sum(r.outcome is Outcome.COMPLETED for r in reqs)
    m = server.metrics
    print(f"done: {args.steps} training steps interleaved with {done} "
          f"served requests; p50={m.latency.percentile(50) * 1e3:.0f}ms "
          f"p99={m.latency.percentile(99) * 1e3:.0f}ms "
          f"occupancy={m.occupancy:.2f}")
    print(f"stats: {server.stats()}")


if __name__ == "__main__":
    main()
