"""The paper's scenario at pod scale: SERVE from a model that is TRAINING.

A trainer commits optimizer steps into the MVStore while a server thread
answers generation requests from consistent parameter snapshots.  In Mode
Q the server's reads abort whenever training commits first (watch the
abort counter); once the store versions parameters (Mode U ring), every
request is served from the newest committed snapshot without ever pausing
training — the long-running-read guarantee of Multiverse.

    PYTHONPATH=src python examples/serve_snapshots.py --steps 30

(For the word-granularity spelling of the same begin/commit vocabulary —
and a store-level handle that speaks it too — see `repro.api` and
examples/quickstart.py; `make_tm("mvstore", ...)` runs this pattern as
literal read-only transactions.)
"""
import argparse
import threading
import time

import numpy as np

from repro.configs import MVStoreConfig, ShapeConfig, smoke_config
from repro.core import mvcontroller, mvstore
from repro.launch.serve import Server
from repro.launch.train import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--requests", type=int, default=6)
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    shape = ShapeConfig("t", 32, 2, "train")
    controller = mvcontroller.MVController(
        mvcfg=MVStoreConfig(ring_slots=2, mode="U"))
    trainer = Trainer(cfg, shape, mvcfg=MVStoreConfig(mode="U"),
                      controller=controller)
    server = Server(cfg, batch=2, prompt_len=16, max_len=32,
                    mvcfg=MVStoreConfig(mode="U"), controller=controller,
                    mv_state=trainer.state.mv)

    served = {"n": 0, "clocks": []}
    stop = threading.Event()

    def serve_loop():
        rng = np.random.default_rng(0)
        while not stop.is_set() and served["n"] < args.requests:
            prompts = rng.integers(0, cfg.vocab_size, size=(2, 16),
                                   dtype=np.int32)
            server.mv_state = trainer.state.mv       # follow the trainer
            out = server.serve_batch(prompts, max_new=8)
            served["n"] += 1
            served["clocks"].append(int(trainer.state.mv.clock))
            print(f"  [server] request {served['n']} generated "
                  f"{out.shape[1]} tokens at clock "
                  f"{served['clocks'][-1]} (aborts so far: "
                  f"{server.aborts})", flush=True)

    th = threading.Thread(target=serve_loop)
    th.start()
    state = trainer.state
    for s in range(args.steps):
        state, metrics = trainer.train_step(state, trainer.batch_at(s))
        trainer.state = state
        if (s + 1) % 10 == 0:
            print(f"[trainer] step {s+1} loss={float(metrics['loss']):.4f}"
                  f" clock={int(state.mv.clock)} "
                  f"rings={len(state.mv.ring)}", flush=True)
    stop.set()
    th.join()
    controller.stop()
    print(f"done: {args.steps} training steps interleaved with "
          f"{served['n']} served requests at clocks {served['clocks']}; "
          f"server aborts={server.aborts}")


if __name__ == "__main__":
    main()
