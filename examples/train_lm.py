"""End-to-end driver: train an LM with the full framework stack.

Uses the real substrate: config registry, MVStore parameter store, AdamW,
deterministic data pipeline, fault-tolerant supervisor with snapshot-
consistent async checkpoints.  Default is a CPU-friendly reduced config;
``--full`` selects the real arch (for accelerator hosts).

    PYTHONPATH=src python examples/train_lm.py --steps 100
    PYTHONPATH=src python examples/train_lm.py --arch mamba2-780m \
        --steps 50 --inject-failure-at 30     # kill a node mid-run
"""
import argparse

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--full", action="store_true",
                    help="full-size config (accelerator hosts)")
    ap.add_argument("--inject-failure-at", type=int, default=-1)
    args = ap.parse_args()
    argv = ["--arch", args.arch, "--steps", str(args.steps),
            "--seq", "64", "--batch", "8",
            "--inject-failure-at", str(args.inject_failure_at)]
    if not args.full:
        argv.append("--smoke")
    raise SystemExit(train_main(argv))


if __name__ == "__main__":
    main()
