"""The paper's benchmark methodology (SS5), scaled to this container.

Workloads mix searches / inserts / deletes / range queries over a
prefilled structure, with DEDICATED UPDATER threads whose operations never
commit read-only and whose throughput is NOT counted (otherwise algorithms
with no real RQ support get propped up — paper Fig. 7).  Python threads
under the GIL make absolute ops/sec meaningless vs the paper's EPYC
numbers; the CLAIMS are relational (Multiverse vs baselines ratios,
starvation behavior) and those reproduce (EXPERIMENTS.md SSClaims).
"""
from __future__ import annotations

import dataclasses
import random
import threading
import time
from typing import Dict, List, Optional

from repro.api import MaxRetriesExceeded, make_tm, run  # noqa: F401
from repro.configs.paper_stm import MultiverseParams, WorkloadConfig
from repro.structs import ABTree, ExternalBST, HashMap

MAX_RETRIES = 2000          # 'maximum allowed aborts' before an op quits

# Backend construction (incl. the Fig. 8 forced-mode variants) now lives in
# the repro.api registry; `make_tm` is re-exported above for the benches.


def make_struct(kind: str, tm):
    if kind == "abtree":
        return ABTree(tm)
    if kind == "hashmap":
        return HashMap(tm, n_buckets=1 << 12)
    return ExternalBST(tm)


def prefill(tm, s, cfg: WorkloadConfig, seed: int = 0):
    rnd = random.Random(42 + seed)
    n = 0
    while n < cfg.prefill:
        k = rnd.randrange(cfg.key_range)
        if run(tm, lambda tx, k=k: s.insert(tx, k, k), tid=0):
            n += 1


@dataclasses.dataclass
class ThreadResult:
    ops: int = 0
    rqs: int = 0
    failed_ops: int = 0
    aborts_seen: int = 0


def worker_loop(tm, s, cfg: WorkloadConfig, tid: int, stop: threading.Event,
                res: ThreadResult, dedicated_updater: bool,
                interval_cb=None, seed: int = 0):
    rnd = random.Random(1000 + tid + seed * 10007)
    is_hash = isinstance(s, HashMap)
    while not stop.is_set():
        if interval_cb is not None:
            cfg = interval_cb()
            if dedicated_updater and cfg.n_dedicated_updaters == 0:
                time.sleep(0.001)     # updaters idle through calm intervals
                continue
        r = rnd.random()
        k = rnd.randrange(cfg.key_range)
        try:
            if dedicated_updater:
                # never commits read-only (paper SS5)
                run(tm, lambda tx: s.upsert_touch(tx, k, k), tid=tid,
                    max_retries=MAX_RETRIES)
                if cfg.updater_sleep_s:
                    time.sleep(cfg.updater_sleep_s)
            elif r < cfg.search_pct:
                run(tm, lambda tx: s.search(tx, k), tid=tid,
                    max_retries=MAX_RETRIES)
            elif r < cfg.search_pct + cfg.rq_pct:
                if is_hash:
                    run(tm, lambda tx: s.size_query(tx), tid=tid,
                        max_retries=MAX_RETRIES)
                else:
                    run(tm, lambda tx: s.range_query(tx, k, cfg.rq_size),
                        tid=tid, max_retries=MAX_RETRIES)
                res.rqs += 1
            elif r < cfg.search_pct + cfg.rq_pct + (
                    1 - cfg.search_pct - cfg.rq_pct) / 2:
                run(tm, lambda tx: s.insert(tx, k, k), tid=tid,
                    max_retries=MAX_RETRIES)
            else:
                run(tm, lambda tx: s.delete(tx, k), tid=tid,
                    max_retries=MAX_RETRIES)
            res.ops += 1
        except MaxRetriesExceeded:
            res.failed_ops += 1


def run_workload(tm_name: str, cfg: WorkloadConfig, *,
                 params: Optional[MultiverseParams] = None,
                 forced_mode: Optional[str] = None,
                 time_series: bool = False,
                 interval_cb_factory=None, seed: int = 0) -> Dict:
    """One trial.  Returns throughput of regular threads only.

    ``seed`` offsets every RNG (prefill + per-worker op streams) so a
    BENCH_*.json trajectory names the exact op sequence it measured —
    thread interleaving stays OS-scheduled, but the work is pinned.
    """
    import sys
    total_threads = cfg.n_threads + cfg.n_dedicated_updaters
    tm = make_tm(tm_name, total_threads, params=params,
                 forced_mode=forced_mode)
    s = make_struct(cfg.structure, tm)
    prefill(tm, s, cfg, seed=seed)
    # fine-grained GIL switching: without this, an entire RQ often runs
    # between two thread switches and dedicated updaters can never
    # interleave (the paper's contention disappears into GIL artifacts)
    old_interval = sys.getswitchinterval()
    sys.setswitchinterval(2e-5)
    stop = threading.Event()
    results = [ThreadResult() for _ in range(total_threads)]
    threads = []
    for t in range(total_threads):
        dedicated = t >= cfg.n_threads
        cb = interval_cb_factory(t) if interval_cb_factory else None
        threads.append(threading.Thread(
            target=worker_loop,
            args=(tm, s, cfg, t, stop, results[t], dedicated, cb, seed)))
    series = []
    t0 = time.time()
    [th.start() for th in threads]
    if time_series:
        while time.time() - t0 < cfg.duration_s:
            time.sleep(0.2)
            series.append((time.time() - t0,
                           sum(r.ops for r in results[:cfg.n_threads])))
    else:
        time.sleep(cfg.duration_s)
    stop.set()
    [th.join() for th in threads]
    sys.setswitchinterval(old_interval)
    dt = time.time() - t0
    regular = results[:cfg.n_threads]
    stats = tm.stats()               # normalized schema on every backend
    tm.stop()
    out = {
        "tm": tm_name + (f"-{forced_mode}" if forced_mode else ""),
        "backend": tm_name,
        "workload": cfg.name,
        "structure": cfg.structure,
        "threads": cfg.n_threads,
        "updaters": cfg.n_dedicated_updaters,
        "seed": seed,
        "ops_per_sec": sum(r.ops for r in regular) / dt,
        "rqs": sum(r.rqs for r in regular),
        "failed_ops": sum(r.failed_ops for r in regular),
        "mode_transitions": stats.get("mode_transitions", 0),
        "stm_stats": {k: v for k, v in stats.items()},
    }
    if time_series:
        out["series"] = series
    return out
