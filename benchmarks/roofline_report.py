"""Render the roofline table from dry-run sweep JSONL files.

Merges fit results (memory proof, both meshes) with probe-reconstructed
metrics (single-pod roofline terms).  Last entry per (arch, shape, mesh,
mv_mode) wins, so re-runs of fixed cells override earlier failures.

  PYTHONPATH=src python -m benchmarks.roofline_report \
      results/dryrun_fit.jsonl results/dryrun_probes.jsonl
"""
from __future__ import annotations

import json
import sys
from typing import Dict, List, Optional


def load_latest(path: str) -> Dict[tuple, dict]:
    out = {}
    with open(path) as f:
        for line in f:
            d = json.loads(line)
            key = (d["arch"], d["shape"], d["mesh"], d.get("mv_mode", "Q"))
            out[key] = d
    return out


def render(fit_path: str, probes_path: Optional[str] = None,
           md_out: Optional[str] = None) -> List[dict]:
    fit = load_latest(fit_path)
    probes = load_latest(probes_path) if probes_path else {}
    rows = []
    for key in sorted(fit):
        arch, shape, mesh, mv = key
        f = fit[key]
        p = probes.get(key, {})
        row = {"arch": arch, "shape": shape, "mesh": mesh, "mv_mode": mv,
               "status": f["status"]}
        if f["status"] == "ok":
            row["peak_gb"] = f["memory"]["peak_bytes_per_device"] / 1e9
            row["compile_s"] = f.get("compile_s")
        if f["status"] == "skipped":
            row["reason"] = f.get("reason", "")
        rl = p.get("roofline") or f.get("roofline")
        if rl:
            row.update({
                "t_compute_s": rl["t_compute_s"],
                "t_memory_s": rl["t_memory_s"],
                "t_collective_s": rl["t_collective_s"],
                "dominant": rl["dominant"],
                "useful_flops_ratio": rl["useful_flops_ratio"],
                "roofline_fraction": rl["roofline_fraction"],
            })
        rows.append(row)
    if md_out:
        with open(md_out, "w") as f:
            f.write(to_markdown(rows))
    return rows


def to_markdown(rows: List[dict]) -> str:
    head = ("| arch | shape | mesh | status | peak GB | t_comp | t_mem | "
            "t_coll | dominant | useful | roofline |\n"
            "|---|---|---|---|---|---|---|---|---|---|---|\n")
    body = []
    for r in rows:
        def fmt(k, scale=1.0, nd=4):
            v = r.get(k)
            return f"{v * scale:.{nd}g}" if isinstance(v, (int, float)) \
                else "-"
        body.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['status']} "
            f"| {fmt('peak_gb', nd=3)} | {fmt('t_compute_s')} "
            f"| {fmt('t_memory_s')} | {fmt('t_collective_s')} "
            f"| {r.get('dominant', '-')} | {fmt('useful_flops_ratio',nd=3)} "
            f"| {fmt('roofline_fraction', nd=3)} |")
    return head + "\n".join(body) + "\n"


def main():
    fit = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun_fit.jsonl"
    probes = sys.argv[2] if len(sys.argv) > 2 else None
    rows = render(fit, probes)
    print(to_markdown(rows))


if __name__ == "__main__":
    main()
