"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (plus figure-specific JSON to
results/).  Scaled to this 1-core container: prefill sizes, durations and
thread counts shrink; ratios and starvation behavior are the claims
(EXPERIMENTS.md SSClaims maps each figure to its validation).

  PYTHONPATH=src python -m benchmarks.run                # everything
  PYTHONPATH=src python -m benchmarks.run fig6 mvstore   # a subset
  PYTHONPATH=src python -m benchmarks.run fig6 --seed 3  # pinned RNG

Every ``bench_*.json`` carries a ``meta`` block (git SHA, seed, backend
set, mode-transition counts per row) so BENCH trajectories across PRs
name exactly what they measured and can be re-run bit-for-bit.
"""
from __future__ import annotations

import dataclasses
import os
import sys
import time

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")

SEED = 0                          # set by --seed; threaded into workloads


def _emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.3f},{derived}", flush=True)


def _save(name: str, rows):
    """Results JSON = {meta, rows} in the shared ``repro.eval.results``
    schema (one writer for everything under results/; the historical
    ``bench_*.json`` names are kept via the prefix)."""
    from repro.eval.results import save_results
    save_results(name, rows, SEED, out_dir=RESULTS_DIR, prefix="bench")


# ---------------------------------------------------------------------------
# Fig. 1 / Fig. 6: (a,b)-tree throughput across TMs and workloads
# ---------------------------------------------------------------------------


def bench_fig6_throughput(structs=("abtree",), quick: bool = False):
    from benchmarks.workload import run_workload
    from repro.configs.paper_stm import MultiverseParams, WorkloadConfig

    tms = ["multiverse", "tl2", "dctl", "norec", "tinystm"]
    rows = []
    for structure in structs:
        # RQ size = full prefill (the paper's RQs span 1%% of 1M keys and
        # take ~ms; here the GIL only interleaves updaters into reads of
        # comparable duration, so RQs scan the whole structure)
        wls = [
            WorkloadConfig("no_rq_0upd", structure=structure, rq_pct=0.0,
                           search_pct=0.90, prefill=3000, key_range=6000,
                           rq_size=3000, n_threads=3, duration_s=1.5),
            WorkloadConfig("rq_0upd", structure=structure, rq_pct=0.005,
                           search_pct=0.895, prefill=3000, key_range=6000,
                           rq_size=3000, n_threads=3, duration_s=1.5),
            WorkloadConfig("no_rq_2upd", structure=structure, rq_pct=0.0,
                           search_pct=0.90, prefill=3000, key_range=6000,
                           rq_size=3000, n_threads=3,
                           n_dedicated_updaters=2, duration_s=1.5),
            WorkloadConfig("rq_2upd", structure=structure, rq_pct=0.005,
                           search_pct=0.895, prefill=3000, key_range=6000,
                           rq_size=3000, n_threads=3,
                           n_dedicated_updaters=2, duration_s=2.5),
        ]
        if quick:
            wls = wls[-1:]
        for wl in wls:
            for tm in tms:
                # K1/K2/K3 count ATTEMPTS; one RQ attempt here costs ~10ms
                # (vs ~0.1ms on the paper's EPYC), so the thresholds scale
                # down by the same ~100x to keep the same wall-clock
                # engagement point (paper SS5 tunables).  One params object
                # for every backend: baselines take the lock-table sizing
                # from it and ignore the Multiverse-only knobs.
                params = MultiverseParams(k1=4, k2=6, k3=6,
                                          lock_table_bits=12)
                r = run_workload(tm, wl, params=params, seed=SEED)
                rows.append(r)
                _emit(f"fig6/{structure}/{wl.name}/{tm}",
                      1e6 / max(r["ops_per_sec"], 1e-9),
                      f"ops/s={r['ops_per_sec']:.0f};rqs={r['rqs']};"
                      f"failed={r['failed_ops']}")
    _save("fig6", rows)
    return rows


def bench_appendix_structs():
    """Hashmap (size queries) + external BST, paper Appendix A."""
    return bench_fig6_throughput(structs=("hashmap", "extbst"),
                                 quick=True)


# ---------------------------------------------------------------------------
# Fig. 8: time-varying workload; mode switching vs forced Q / forced U
# ---------------------------------------------------------------------------


def bench_fig8_timevarying():
    from benchmarks.workload import run_workload
    from repro.configs.paper_stm import MultiverseParams, WorkloadConfig

    base = dict(structure="abtree", prefill=2000, key_range=4000,
                rq_size=2000, n_threads=2, duration_s=4.0)
    # calm: point ops only, updaters idle; stormy: RQs + active updaters
    # (paper Fig. 8's interval structure)
    calm = WorkloadConfig("calm", rq_pct=0.0, search_pct=0.80,
                          n_dedicated_updaters=0, **base)
    stormy = WorkloadConfig("stormy", rq_pct=0.02, search_pct=0.78,
                            n_dedicated_updaters=2, **base)

    def interval_factory(tid):
        t0 = time.time()

        def cb():
            # 1s calm / 1s stormy intervals
            return stormy if int(time.time() - t0) % 2 else calm
        return cb

    # spawn with updater slots present; the interval callback idles them
    spawn = dataclasses.replace(calm, n_dedicated_updaters=2)
    rows = []
    for variant, forced in [("adaptive", None), ("forcedQ", "Q"),
                            ("forcedU", "U")]:
        r = run_workload("multiverse", spawn, forced_mode=forced,
                         params=MultiverseParams(lock_table_bits=12),
                         time_series=True,
                         interval_cb_factory=interval_factory, seed=SEED)
        r["variant"] = variant
        rows.append(r)
        _emit(f"fig8/{variant}", 1e6 / max(r["ops_per_sec"], 1e-9),
              f"ops/s={r['ops_per_sec']:.0f};"
              f"transitions={r['stm_stats']['mode_transitions']}")
    _save("fig8", rows)
    return rows


# ---------------------------------------------------------------------------
# Fig. 9: memory — version-node footprint, with vs without RQs
# ---------------------------------------------------------------------------


def bench_fig9_memory():
    """Dynamic multiversioning pays for versions only while RQs need
    them: track live version nodes under both workloads."""
    import threading
    from benchmarks.workload import (ThreadResult, make_struct, make_tm,
                                     prefill, worker_loop)
    from repro.configs.paper_stm import WorkloadConfig

    rows = []
    for name, rq_pct in [("no_rq", 0.0), ("rq", 0.02)]:
        # low base contention (big key range, 1 reader) so Mode-Q stays
        # version-free without RQs — versions appear only when RQs do
        wl = WorkloadConfig(f"mem_{name}", rq_pct=rq_pct,
                            search_pct=0.88 - rq_pct, prefill=3000,
                            key_range=50000, rq_size=3000, n_threads=1,
                            n_dedicated_updaters=1, duration_s=2.0,
                            updater_sleep_s=3e-4)
        import sys as _sys
        old_si = _sys.getswitchinterval()
        _sys.setswitchinterval(2e-5)   # fine interleave: no GIL bursts
        from repro.configs.paper_stm import MultiverseParams
        tm = make_tm("multiverse", 2,
                     params=MultiverseParams(k1=5, lock_table_bits=12))
        s = make_struct("abtree", tm)
        prefill(tm, s, wl)
        stop = threading.Event()
        res = [ThreadResult() for _ in range(2)]
        ths = [threading.Thread(
            target=worker_loop,
            args=(tm, s, wl, t, stop, res[t], t >= 1, None, SEED))
               for t in range(2)]
        [t.start() for t in ths]
        peak_nodes = 0
        t0 = time.time()
        while time.time() - t0 < wl.duration_s:
            time.sleep(0.1)
            nodes = 0
            for b in tm.vlt.nonempty_buckets():
                node = tm.vlt._buckets[b]
                while node is not None:
                    v = node.vlist.head
                    while v is not None:
                        nodes += 1
                        v = v.older
                    node = node.next
            peak_nodes = max(peak_nodes, nodes)
        stop.set()
        [t.join() for t in ths]
        _sys.setswitchinterval(old_si)
        st = tm.stats()
        tm.stop()
        rows.append({"workload": name, "peak_version_nodes": peak_nodes,
                     "unversioned_buckets": st["unversioned_buckets"],
                     "ebr_freed": st["ebr_freed"]})
        _emit(f"fig9/{name}", float(peak_nodes),
              f"peak_version_nodes={peak_nodes};"
              f"freed={st['ebr_freed']}")
    _save("fig9", rows)
    return rows


# ---------------------------------------------------------------------------
# MVStore: Mode-Q vs Mode-U step overhead + snapshot behavior (Layer B)
# ---------------------------------------------------------------------------


def bench_mvstore():
    import jax
    from repro.configs import MVStoreConfig, ShapeConfig, smoke_config
    from repro.core import mvstore
    from repro.launch.train import Trainer

    cfg = smoke_config("qwen2.5-3b")
    shape = ShapeConfig("b", 64, 4, "train")
    rows = []
    for mode in ("Q", "U"):
        tr = Trainer(cfg, shape, mvcfg=MVStoreConfig(mode=mode))
        state = tr.state
        for s in range(3):
            state, m = tr.train_step(state, tr.batch_at(s))
        jax.block_until_ready(m["loss"])
        t0 = time.time()
        n = 10
        for s in range(3, 3 + n):
            state, m = tr.train_step(state, tr.batch_at(s))
        jax.block_until_ready(m["loss"])
        dt = (time.time() - t0) / n
        t1 = time.time()
        view, ok = mvstore.mv_snapshot(state.mv, int(state.mv.clock))
        jax.block_until_ready(jax.tree.leaves(view)[0])
        snap_s = time.time() - t1
        stale_ok = bool(mvstore.mv_snapshot(state.mv,
                                            int(state.mv.clock) - 1)[1])
        tr.controller.stop()
        rows.append({"mode": mode, "step_s": dt, "snapshot_s": snap_s,
                     "stale_read_ok": stale_ok,
                     "ring_bytes": mvstore.ring_bytes(state.mv)})
        _emit(f"mvstore/mode{mode}", dt * 1e6,
              f"snapshot_us={snap_s*1e6:.0f};stale_ok={stale_ok};"
              f"ring_bytes={mvstore.ring_bytes(state.mv)}")
    # Mode U must serve stale reads that Mode Q aborts
    assert rows[1]["stale_read_ok"] and not rows[0]["stale_read_ok"]
    _save("mvstore", rows)
    return rows


# ---------------------------------------------------------------------------
# Kernel microbenches (interpret mode — correctness-path timing only)
# ---------------------------------------------------------------------------


def bench_kernels():
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops

    rows = []
    key = jax.random.PRNGKey(0)
    B, S, H, KV, D = 1, 256, 4, 2, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, D), jnp.float32)

    def timeit(fn, n=3):
        fn()
        t0 = time.time()
        for _ in range(n):
            jax.block_until_ready(fn())
        return (time.time() - t0) / n

    t = timeit(lambda: ops.flash_attention(q, k, v, causal=True,
                                           block_q=64, block_k=64))
    _emit("kernels/flash_attention_interp", t * 1e6, f"S={S};H={H};D={D}")
    rows.append({"kernel": "flash_attention", "seconds": t})

    ring = jax.random.normal(key, (4, 1024, 64), jnp.float32)
    ts = jnp.asarray([1, 5, 3, -1], jnp.int32)
    t = timeit(lambda: ops.snapshot_select(ring, ts, jnp.int32(4)))
    _emit("kernels/snapshot_select_interp", t * 1e6, "R=4;n=64k")
    rows.append({"kernel": "snapshot_select", "seconds": t})
    _save("kernels", rows)
    return rows


# ---------------------------------------------------------------------------
# Group commit + rwmix headline (PR 7) — persisted under bench_*.json so CI
# leaves both artifacts in the shared results schema
# ---------------------------------------------------------------------------


def bench_groupcommit():
    """Group-commit microbench: N solo commit pipelines vs one fused
    batch of disjoint transactions (examples/bakeoff.py owns the
    measurement loop; this wrapper persists rows to results/)."""
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from examples.bakeoff import groupcommit_microbench

    rows = groupcommit_microbench(n_txns=(2, 4, 8))
    for r in rows:
        r["backend"] = "tl2"          # meta.backends in the shared schema
        _emit(f"groupcommit/txns{r['txns']}", r["grouped_us"],
              f"solo_us={r['solo_us']:.1f};speedup={r['speedup']:.2f}x")
    _save("groupcommit", rows)
    return rows


def bench_rwmix():
    """Write-heavy eval headline re-saved under the bench_ prefix: the
    eval CLI writes eval_rwmix.json; CI's results artifact wants the
    same rows (plus the headline ratio) as bench_rwmix.json."""
    from repro.eval.driver import run_eval, rwmix_headline
    from repro.eval.results import save_results

    rows, _ = run_eval("rwmix", seed=SEED, quick=True, save=False)
    head = rwmix_headline(rows)
    for r in rows:
        _emit(f"rwmix/{r.get('variant', '?')}/{r['backend']}",
              1e6 / max(r.get("updates_per_sec", 0.0), 1e-9),
              f"upd/s={r.get('updates_per_sec', 0.0):.0f};"
              f"violations={r.get('violations', 0)}")
    save_results("rwmix", rows, SEED, out_dir=RESULTS_DIR,
                 extra_meta={"headline": head}, prefix="bench")
    return rows


def bench_shardscale():
    """Shard-scaling eval headline re-saved under the bench_ prefix:
    two disjoint-block updaters over the same total heap words at 1 and
    2 shards; the headline is the 2-shard throughput ratio (>=1.6x),
    the shard==1 bit-parity check vs mvstore, and the zero-violation
    gate (CI's results artifact wants bench_shardscale.json next to
    the other bench_*.json)."""
    from repro.eval.driver import run_eval, shardscale_headline
    from repro.eval.results import save_results

    rows, _ = run_eval("shardscale", seed=SEED, quick=True, save=False)
    head = shardscale_headline(rows)
    for r in rows:
        _emit(f"shardscale/{r.get('variant', '?')}/{r['backend']}",
              1e6 / max(r.get("updates_per_sec", 0.0), 1e-9),
              f"upd/s={r.get('updates_per_sec', 0.0):.0f};"
              f"shards={r.get('n_shards', 1)};"
              f"parity={r.get('parity_ok')};"
              f"violations={r.get('violations', 0)}")
    save_results("shardscale", rows, SEED, out_dir=RESULTS_DIR,
                 extra_meta={"headline": head}, prefix="bench")
    return rows


def bench_reliability():
    """Crash-recovery eval headline re-saved under the bench_ prefix:
    rwmix rotations under a seeded kill schedule, recovery after every
    kill, zero-violation gate (CI's results artifact wants
    bench_reliability.json next to the other bench_*.json)."""
    from repro.eval.driver import reliability_headline, run_eval
    from repro.eval.results import save_results

    rows, _ = run_eval("reliability", seed=SEED, quick=True, save=False)
    head = reliability_headline(rows)
    for r in rows:
        _emit(f"reliability/{r.get('variant', '?')}/{r['backend']}",
              1e6 / max(r.get("updates_per_sec", 0.0), 1e-9),
              f"upd/s={r.get('updates_per_sec', 0.0):.0f};"
              f"kills={r.get('kills', 0)};"
              f"recovered={r.get('recoveries', 0)};"
              f"violations={r.get('violations', 0)}")
    save_results("reliability", rows, SEED, out_dir=RESULTS_DIR,
                 extra_meta={"headline": head}, prefix="bench")
    return rows


def bench_durability():
    """Durable-commit eval headline re-saved under the bench_ prefix:
    rwmix rotations with vs without the fsync'd write-ahead commit log,
    plus the end-of-trial restart drill (a FRESH engine replays the log
    and every block sum must be conserved).  The headline gate is
    durable >= 0.5x in-memory throughput with zero violations (CI's
    results artifact wants bench_durability.json next to the other
    bench_*.json)."""
    from repro.eval.driver import durability_headline, run_eval
    from repro.eval.results import save_results

    rows, _ = run_eval("durability", seed=SEED, quick=True, save=False)
    head = durability_headline(rows)
    for r in rows:
        _emit(f"durability/{r.get('variant', '?')}/{r['backend']}",
              1e6 / max(r.get("updates_per_sec", 0.0), 1e-9),
              f"upd/s={r.get('updates_per_sec', 0.0):.0f};"
              f"fsyncs={r.get('wal_stats', {}).get('fsyncs', 0)};"
              f"replayed={r.get('wal_records_replayed', 0)};"
              f"violations={r.get('violations', 0)}")
    save_results("durability", rows, SEED, out_dir=RESULTS_DIR,
                 extra_meta={"headline": head}, prefix="bench")
    return rows


# ---------------------------------------------------------------------------
# Roofline report (reads the dry-run sweep results)
# ---------------------------------------------------------------------------


def bench_roofline_report():
    from benchmarks.roofline_report import render
    fit = os.path.join(RESULTS_DIR, "dryrun_fit.jsonl")
    probes = os.path.join(RESULTS_DIR, "dryrun_probes.jsonl")
    if not os.path.exists(fit):
        _emit("roofline/skipped", 0.0, "no dry-run results found")
        return []
    rows = render(fit, probes if os.path.exists(probes) else None)
    for r in rows:
        if r.get("roofline_fraction") is not None:
            _emit(f"roofline/{r['arch']}/{r['shape']}", 0.0,
                  f"dominant={r.get('dominant')};"
                  f"frac={r['roofline_fraction']:.3f}")
    return rows


BENCHES = {
    "fig6": bench_fig6_throughput,
    "appendix": bench_appendix_structs,
    "fig8": bench_fig8_timevarying,
    "fig9": bench_fig9_memory,
    "mvstore": bench_mvstore,
    "kernels": bench_kernels,
    "groupcommit": bench_groupcommit,
    "rwmix": bench_rwmix,
    "shardscale": bench_shardscale,
    "reliability": bench_reliability,
    "durability": bench_durability,
    "roofline": bench_roofline_report,
}


def main() -> None:
    global SEED
    argv = sys.argv[1:]
    if "--seed" in argv:
        i = argv.index("--seed")
        try:
            SEED = int(argv[i + 1])
        except (IndexError, ValueError):
            sys.exit("usage: benchmarks.run [bench ...] [--seed INT]")
        del argv[i:i + 2]
    which = [a for a in argv if a in BENCHES] or list(BENCHES)
    print("name,us_per_call,derived")
    for name in which:
        t0 = time.time()
        try:
            BENCHES[name]()
        except Exception as e:  # noqa: BLE001
            _emit(f"{name}/ERROR", 0.0, repr(e)[:160])
        _emit(f"{name}/total_wall", (time.time() - t0) * 1e6, "")


if __name__ == "__main__":
    main()
